"""Public wrapper: Pallas-accelerated envelope computation for generation.

``envelopes_pallas`` returns M(t), m(t) in the exact layout the core numpy
path (`repro.core.designspace.envelopes`) produces, so the generator can swap
implementations freely (``impl="pallas"`` in benchmarks).

``region_envelopes_device`` is the batched-engine entry point: one
``pallas_call`` over a grid of regions plus an on-device parity merge,
Eqn 9 feasibility, and the Eqn 7-8 a-interval divided-difference reduction —
the whole §II front half for all ``2^R`` regions in a single compiled
program (compiled on TPU, interpret elsewhere).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dspace.kernel import (BIG, TILE, envelopes_parity,
                                         envelopes_parity_batched,
                                         envelopes_parity_fleet)
from repro.kernels.dspace.ref import envelopes_parity_ref

_PAD_L = -(2.0 ** 30)  # pad-lane sentinels: see envelopes_pallas docstring
_PAD_U = 2.0 ** 30


def _interleave(me, mo, be, bo, n: int):
    """Parity arrays -> (M, m) indexed by t in [0, 2n-2); index 0 is padding."""
    m = np.empty(2 * n - 2, dtype=np.float64)
    big_m = np.empty(2 * n - 2, dtype=np.float64)
    m[0::2] = np.asarray(me)[: n - 1]
    m[1::2] = np.asarray(mo)[: n - 1]
    big_m[0::2] = np.asarray(be)[: n - 1]
    big_m[1::2] = np.asarray(bo)[: n - 1]
    m[0], big_m[0] = np.inf, -np.inf
    m[m >= 3.0e38] = np.inf
    big_m[big_m <= -3.0e38] = -np.inf
    return big_m, m


def envelopes_pallas(L: np.ndarray, U: np.ndarray, interpret: bool = True
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Drop-in replacement for core.designspace.envelopes via the kernel.

    Pads N up to a TILE multiple; pad lanes only ever appear as the *right*
    (y) operand of a kept-lane pair, so L[pad] = -2^30 / U[pad] = +2^30 make
    every pad-touching divided difference lose its min/max reduction.
    """
    n = len(L)
    if n < 2:
        return np.full(1, -np.inf), np.full(1, np.inf)
    n_pad = max(((n + TILE - 1) // TILE) * TILE, TILE)
    lp = np.zeros(n_pad, np.float64)
    up = np.zeros(n_pad, np.float64)
    lp[:n], up[:n] = L, U
    if n_pad > n:
        lp[n:] = -(2.0**30)  # d_lo = (L[y]-U[x]-1)/.. -> -huge, loses max
        up[n:] = 2.0**30  # d_up = (U[y]+1-L[x])/.. -> +huge, loses min
    me, mo, be, bo = envelopes_parity(jnp.asarray(lp), jnp.asarray(up), interpret)
    big_m, m = _interleave(me, mo, be, bo, n_pad)
    return big_m[: 2 * n - 2], m[: 2 * n - 2]


def envelopes_ref_jnp(L: np.ndarray, U: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    n = len(L)
    if n < 2:
        return np.full(1, -np.inf), np.full(1, np.inf)
    me, mo, be, bo = envelopes_parity_ref(jnp.asarray(L), jnp.asarray(U))
    return _interleave(me, mo, be, bo, n)


# ---------------------------------------------------------------------------
# Batched engine: all regions in one device program
# ---------------------------------------------------------------------------

def _dd_max_rows(g: jax.Array, h: jax.Array) -> jax.Array:
    """Row-wise max_{x<y} (g[y]-h[x])/(y-x) on device, O(T^2) masked sweep.

    Right-pads ``g`` with ``-BIG`` so out-of-range y operands lose every max
    reduction (the padded slope keeps magnitude >= BIG / T, far below/above
    any real envelope slope)."""
    bsz, t = g.shape
    gp = jnp.pad(g, ((0, 0), (0, t)), constant_values=-BIG)

    def body(delta, best):
        gy = jax.lax.dynamic_slice(gp, (0, delta), (bsz, t))
        d = (gy - h) / delta.astype(jnp.float32)
        return jnp.maximum(best, jnp.max(d, axis=1))

    return jax.lax.fori_loop(1, t, body, jnp.full(bsz, -BIG, jnp.float32))


def _merge_reduce(me, mo, be, bo, n_real: int):
    """On-device parity merge (t = 2j -> even slot, t = 2j+1 -> odd slot),
    Eqn 9 feasibility, and the Eqn 7-8 a-interval reduction over stacked
    parity rows ``(rows, n_pad)``."""
    b, n_pad = me.shape
    m = jnp.stack([me[:, : n_pad - 1], mo[:, : n_pad - 1]], axis=2)
    big = jnp.stack([be[:, : n_pad - 1], bo[:, : n_pad - 1]], axis=2)
    m = m.reshape(b, 2 * n_pad - 2)[:, : 2 * n_real - 2]
    big = big.reshape(b, 2 * n_pad - 2)[:, : 2 * n_real - 2]
    mt, st = big[:, 1:], m[:, 1:]  # valid t range
    feas9 = jnp.all(mt < st, axis=1)
    a_lo = _dd_max_rows(mt, st)
    a_hi = -_dd_max_rows(-st, -mt)
    return big, m, a_lo, a_hi, feas9


@functools.partial(jax.jit, static_argnames=("n_real", "interpret"))
def _region_spaces_jit(l2: jax.Array, u2: jax.Array, n_real: int,
                       interpret: bool):
    """One pallas_call (grid over regions) + on-device parity merge,
    Eqn 9 feasibility, and the Eqn 7-8 a-interval reduction."""
    me, mo, be, bo = envelopes_parity_batched(l2, u2, interpret)
    return _merge_reduce(me, mo, be, bo, n_real)


def region_envelopes_device(L: np.ndarray, U: np.ndarray,
                            interpret: bool | None = None
                            ) -> tuple[np.ndarray, ...]:
    """§II front half for ALL regions: (M, m, a_lo, a_hi, feas9) arrays.

    ``interpret=None`` auto-selects: compiled on TPU, interpret elsewhere
    (the CPU Pallas lowering only exists in interpret mode). M/m come back
    float64 in the core layout (index 0 placeholder, sentinels -> inf);
    envelope arithmetic itself runs in float32 — see DESIGN.md §9.
    """
    L = np.asarray(L)
    U = np.asarray(U)
    b, n = L.shape
    assert n >= 3, "trivial region widths are handled by the numpy engine"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_pad = max(-(-n // TILE) * TILE, TILE)
    lp = np.full((b, n_pad), _PAD_L)
    up = np.full((b, n_pad), _PAD_U)
    lp[:, :n] = L
    up[:, :n] = U
    big, m, a_lo, a_hi, feas9 = _region_spaces_jit(
        jnp.asarray(lp, jnp.float32), jnp.asarray(up, jnp.float32),
        n_real=n, interpret=bool(interpret))
    big = np.asarray(big, np.float64)
    m = np.asarray(m, np.float64)
    m[m >= 3.0e38] = np.inf
    big[big <= -3.0e38] = -np.inf
    m[:, 0] = np.inf
    big[:, 0] = -np.inf
    return (big, m, np.asarray(a_lo, np.float64), np.asarray(a_hi, np.float64),
            np.asarray(feas9))


# ---------------------------------------------------------------------------
# Fleet engine: stacked (probe, region) grid, probe axis sharded over devices
# ---------------------------------------------------------------------------

def _fleet_impl(l3: jax.Array, u3: jax.Array, *, n_real: int,
                interpret: bool):
    """Per-shard fleet body: one pallas_call over the (probe, region, tile)
    grid plus the parity merge / feasibility / a-interval reduction on the
    flattened (probe*region) rows. Runs unchanged under shard_map — every
    row is independent, so sharding the probe axis is embarrassing."""
    p, b, n_pad = l3.shape
    me, mo, be, bo = envelopes_parity_fleet(l3, u3, interpret)

    def flat(a):
        return a.reshape(p * b, n_pad)

    big, m, a_lo, a_hi, feas9 = _merge_reduce(flat(me), flat(mo), flat(be),
                                              flat(bo), n_real)
    t = big.shape[1]
    return (big.reshape(p, b, t), m.reshape(p, b, t),
            a_lo.reshape(p, b), a_hi.reshape(p, b), feas9.reshape(p, b))


def _resolve_shard_map():
    try:
        from jax.experimental.shard_map import shard_map

        return shard_map
    except ImportError:  # pragma: no cover - moved out of experimental
        return getattr(jax, "shard_map", None)


@functools.lru_cache(maxsize=32)
def _fleet_fn(shards: int, n_real: int, interpret: bool):
    """Compiled fleet front half for a device count (1 = single program;
    > 1 = shard_map over the probe axis). When shard_map is unavailable the
    single vectorized program stands in — the batched grid already covers
    every (probe, region) row, it just runs on one device."""
    impl = functools.partial(_fleet_impl, n_real=n_real, interpret=interpret)
    shard_map = _resolve_shard_map() if shards > 1 else None
    if shards <= 1 or shard_map is None:
        return jax.jit(impl)
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.array(jax.devices()[:shards]), ("probe",))
    spec = P("probe")
    # check_rep=False: the replication checker cannot see through
    # pallas_call; every output is honestly probe-sharded anyway
    return jax.jit(shard_map(impl, mesh=mesh, in_specs=(spec, spec),
                             out_specs=(spec,) * 5, check_rep=False))


def fleet_region_envelopes_device(L3, U3, shards: int | None = None,
                                  interpret: bool | None = None
                                  ) -> tuple[np.ndarray, ...]:
    """§II front half for a stacked probe fleet ``(P, B, N)``: one device
    program with a grid over (probe, region), the probe axis sharded over
    ``shards`` devices (``None``/1 = single program; capped at the local
    device count).

    Returns ``(M, m, a_lo, a_hi, feas9)`` flattened to probe-major rows
    ``(P*B, ...)`` in the core float64 layout. Float32 envelope arithmetic —
    the DESIGN.md §4/§9 contract (a marginal verdict can cost a retry, never
    an unsound artifact). Fleet ``±inf`` column sentinels are clamped to the
    kernel's finite pad values, which lose every reduction the same way.
    """
    L3 = np.asarray(L3)
    U3 = np.asarray(U3)
    p, b, n = L3.shape
    assert n >= 3, "trivial region widths are handled by the numpy engine"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shards = 1 if shards is None else max(1, min(int(shards),
                                                 len(jax.devices())))
    n_pad = max(-(-n // TILE) * TILE, TILE)
    p_pad = -(-p // shards) * shards  # sentinel probes pad the shard axis
    lp = np.full((p_pad, b, n_pad), _PAD_L)
    up = np.full((p_pad, b, n_pad), _PAD_U)
    lp[:p, :, :n] = np.where(np.isfinite(L3), L3, _PAD_L)
    up[:p, :, :n] = np.where(np.isfinite(U3), U3, _PAD_U)
    # n (the real width), NOT n_pad: the merge slices the TILE-pad t-slots
    # off before the a-interval reduction — their ~±2^30/(2e) sentinel
    # envelopes would otherwise win the dd max against steep real tables
    fn = _fleet_fn(shards, n, bool(interpret))
    big, m, a_lo, a_hi, feas9 = fn(jnp.asarray(lp, jnp.float32),
                                   jnp.asarray(up, jnp.float32))
    t = big.shape[-1]
    big = np.asarray(big, np.float64)[:p].reshape(p * b, t)
    m = np.asarray(m, np.float64)[:p].reshape(p * b, t)
    m[m >= 3.0e38] = np.inf
    big[big <= -3.0e38] = -np.inf
    m[:, 0] = np.inf
    big[:, 0] = -np.inf
    return (big, m,
            np.asarray(a_lo, np.float64)[:p].reshape(p * b),
            np.asarray(a_hi, np.float64)[:p].reshape(p * b),
            np.asarray(feas9)[:p].reshape(p * b))
