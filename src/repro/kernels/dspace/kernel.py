"""Pallas TPU kernel: design-space envelope computation (paper §II-A).

The generation hot spot is, per region, the pair of per-sum-t envelopes over
divided differences of the integer bounds L, U:

    m(t) = min_{x<y, x+y=t} (U[y]+1-L[x])/(y-x)
    M(t) = max_{x<y, x+y=t} (L[y]-U[x]-1)/(y-x)

Splitting by the parity of t turns both into center-stencil reductions
(DESIGN.md §4):

    m_even[j] = min_{e>=1} (U[j+e]+1-L[j-e]) / (2e)        (t = 2j)
    m_odd[j]  = min_{e>=0} (U[j+1+e]+1-L[j-e]) / (2e+1)    (t = 2j+1)

which map onto the TPU as: L/U rows padded to 3N and resident in VMEM
(N <= 8192 -> ~200 KiB), grid over j-tiles of 128 lanes, fori_loop over the
offset e with always-in-bounds dynamic slices plus per-lane validity masks.
O(N^2) work with unit-stride vector loads and no scatters — the TPU-native
replacement for the paper's PyPy scalar loops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128
BIG = 3.4e38  # python float: becomes an inline constant, not a captured array


def _parity_reduce(l_row, u_row, j0, n: int):
    """Shared kernel body: the per-offset parity-split center-stencil
    reduction over one padded (1, 3n) row at tile start ``j0``. Returns
    (m_even, m_odd, M_even, M_odd) tiles of shape (1, TILE)."""
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, TILE), 1)
    j = j0 + lane  # global center indices, (1, TILE)

    def body(e, carry):
        me, mo, be, bo = carry
        # padded-row starts are always in bounds: start in [1, 3n - TILE]
        l_lo = jax.lax.dynamic_slice(l_row, (0, j0 - e + n), (1, TILE))
        u_lo = jax.lax.dynamic_slice(u_row, (0, j0 - e + n), (1, TILE))
        u_hi_e = jax.lax.dynamic_slice(u_row, (0, j0 + e + n), (1, TILE))
        l_hi_e = jax.lax.dynamic_slice(l_row, (0, j0 + e + n), (1, TILE))
        u_hi_o = jax.lax.dynamic_slice(u_row, (0, j0 + 1 + e + n), (1, TILE))
        l_hi_o = jax.lax.dynamic_slice(l_row, (0, j0 + 1 + e + n), (1, TILE))
        ok_lo = (j - e) >= 0
        ef = e.astype(jnp.float32)
        # even: pairs (j-e, j+e), e >= 1
        ok_e = ok_lo & ((j + e) <= (n - 1)) & (e >= 1)
        de_up = (u_hi_e + 1.0 - l_lo) / (2.0 * ef)
        de_lo = (l_hi_e - u_lo - 1.0) / (2.0 * ef)
        me = jnp.minimum(me, jnp.where(ok_e, de_up, BIG))
        be = jnp.maximum(be, jnp.where(ok_e, de_lo, -BIG))
        # odd: pairs (j-e, j+1+e), e >= 0
        ok_o = ok_lo & ((j + 1 + e) <= (n - 1))
        do_up = (u_hi_o + 1.0 - l_lo) / (2.0 * ef + 1.0)
        do_lo = (l_hi_o - u_lo - 1.0) / (2.0 * ef + 1.0)
        mo = jnp.minimum(mo, jnp.where(ok_o, do_up, BIG))
        bo = jnp.maximum(bo, jnp.where(ok_o, do_lo, -BIG))
        return me, mo, be, bo

    init = (jnp.full((1, TILE), BIG, jnp.float32), jnp.full((1, TILE), BIG, jnp.float32),
            jnp.full((1, TILE), -BIG, jnp.float32), jnp.full((1, TILE), -BIG, jnp.float32))
    return jax.lax.fori_loop(0, n, body, init)


def _envelope_kernel(l_ref, u_ref, me_ref, mo_ref, be_ref, bo_ref, *, n: int,
                     tile_axis: int = 0):
    """Inputs are rows padded to (1, 3n): real data in [n, 2n).

    me/mo: m(t) even/odd; be/bo: M(t) even/odd. ``tile_axis`` is the grid
    axis carrying the j-tile index (axis 1 when a leading region axis is
    present, as in ``envelopes_parity_batched``).
    """
    j0 = pl.program_id(tile_axis) * TILE
    me, mo, be, bo = _parity_reduce(l_ref[...], u_ref[...], j0, n)
    me_ref[...] = me
    mo_ref[...] = mo
    be_ref[...] = be
    bo_ref[...] = bo


def _envelope_kernel_fleet(l_ref, u_ref, me_ref, mo_ref, be_ref, bo_ref, *,
                           n: int):
    """Fleet variant: blocks carry a (probe, region) prefix — grid axes are
    (probe, region, j-tile) — and the row body is shared."""
    j0 = pl.program_id(2) * TILE
    me, mo, be, bo = _parity_reduce(l_ref[...].reshape(1, -1),
                                    u_ref[...].reshape(1, -1), j0, n)
    me_ref[...] = me.reshape(1, 1, TILE)
    mo_ref[...] = mo.reshape(1, 1, TILE)
    be_ref[...] = be.reshape(1, 1, TILE)
    bo_ref[...] = bo.reshape(1, 1, TILE)


def envelopes_parity(l_arr: jax.Array, u_arr: jax.Array,
                     interpret: bool = True) -> tuple[jax.Array, ...]:
    """Returns (m_even, m_odd, M_even, M_odd), each (N,) float32.

    Entries without any valid pair hold +/-3.4e38 sentinels.
    """
    n = l_arr.shape[-1]
    assert n % TILE == 0 and n >= TILE, n
    l2 = jnp.pad(l_arr.astype(jnp.float32), (n, n)).reshape(1, 3 * n)
    u2 = jnp.pad(u_arr.astype(jnp.float32), (n, n)).reshape(1, 3 * n)
    kernel = functools.partial(_envelope_kernel, n=n)
    out_spec = pl.BlockSpec((1, TILE), lambda i: (0, i))
    shape = jax.ShapeDtypeStruct((1, n), jnp.float32)
    me, mo, be, bo = pl.pallas_call(
        kernel,
        grid=(n // TILE,),
        in_specs=[pl.BlockSpec((1, 3 * n), lambda i: (0, 0))] * 2,
        out_specs=[out_spec] * 4,
        out_shape=[shape] * 4,
        interpret=interpret,
    )(l2, u2)
    return me[0], mo[0], be[0], bo[0]


def envelopes_parity_fleet(l_arr: jax.Array, u_arr: jax.Array,
                           interpret: bool = True) -> tuple[jax.Array, ...]:
    """Fleet-stacked variant: ``(P, B, n)`` probe stacks in, four
    ``(P, B, n)`` parity envelopes out of ONE ``pallas_call`` with grid
    ``(probe, region, n // TILE)``.

    This is the §V scale move one level up from ``envelopes_parity_batched``:
    the whole manifest's probes become one device program whose probe axis
    the fleet engine shards across devices (kernels/dspace/ops.py).
    """
    p, b, n = l_arr.shape
    assert n % TILE == 0 and n >= TILE, n
    l2 = jnp.pad(l_arr.astype(jnp.float32), ((0, 0), (0, 0), (n, n)))
    u2 = jnp.pad(u_arr.astype(jnp.float32), ((0, 0), (0, 0), (n, n)))
    kernel = functools.partial(_envelope_kernel_fleet, n=n)
    out_spec = pl.BlockSpec((1, 1, TILE), lambda q, r, i: (q, r, i))
    shape = jax.ShapeDtypeStruct((p, b, n), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(p, b, n // TILE),
        in_specs=[pl.BlockSpec((1, 1, 3 * n), lambda q, r, i: (q, r, 0))] * 2,
        out_specs=[out_spec] * 4,
        out_shape=[shape] * 4,
        interpret=interpret,
    )(l2, u2)


def envelopes_parity_batched(l_arr: jax.Array, u_arr: jax.Array,
                             interpret: bool = True) -> tuple[jax.Array, ...]:
    """Batched-region variant: ``(B, n)`` rows in, four ``(B, n)`` parity
    envelopes out of ONE ``pallas_call`` with grid ``(B, n // TILE)``.

    This is what lets the generator replace ``2^R`` per-region pool
    round-trips with a single device program (core/batched.py).
    """
    b, n = l_arr.shape
    assert n % TILE == 0 and n >= TILE, n
    l2 = jnp.pad(l_arr.astype(jnp.float32), ((0, 0), (n, n)))
    u2 = jnp.pad(u_arr.astype(jnp.float32), ((0, 0), (n, n)))
    kernel = functools.partial(_envelope_kernel, n=n, tile_axis=1)
    out_spec = pl.BlockSpec((1, TILE), lambda r, i: (r, i))
    shape = jax.ShapeDtypeStruct((b, n), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(b, n // TILE),
        in_specs=[pl.BlockSpec((1, 3 * n), lambda r, i: (r, 0))] * 2,
        out_specs=[out_spec] * 4,
        out_shape=[shape] * 4,
        interpret=interpret,
    )(l2, u2)
