"""Oracle for the fused rmsnorm kernel (identical math, plain jnp gather)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_rmsnorm_lib_ref(x, gamma, coeffs, meta, eps=1e-6):
    """jnp oracle of the library-bound fused RMSNorm kernel: slice the rsqrt
    rows out of the padded (F, R_max, 3) ROM, then the identical glue."""
    from repro.kernels.softmax.ref import _rom_rows

    return fused_rmsnorm_ref(x, gamma, _rom_rows(coeffs, meta), meta, eps)


def fused_rmsnorm_ref(x, gamma, coeffs, meta, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True) + eps
    bits = jax.lax.bitcast_convert_type(ms, jnp.int32)
    e = jnp.bitwise_and(jax.lax.shift_right_logical(bits, 23), 255) - 127
    mant = jnp.bitwise_and(bits, (1 << 23) - 1)
    b = meta["in_bits"]
    halfcode = 1 << (b - 1)
    rnd = 1 << (23 - (b - 1) - 1)
    frac_code = jnp.clip(jax.lax.shift_right_logical(mant + rnd, 23 - (b - 1)),
                         0, halfcode - 1)
    even = jnp.bitwise_and(e, 1) == 0
    codes = jnp.where(even, frac_code, halfcode + frac_code).astype(jnp.int32)
    h = jnp.where(even, e // 2, (e - 1) // 2)
    ev = meta["eval"]
    if ev.get("seg") is not None:  # ROM v2 slot: segment-index datapath
        from repro.kernels.interp.ref import interp_eval_seg_ref

        tab = interp_eval_seg_ref(codes, coeffs,
                                  seg=ev["seg"]).astype(jnp.float32)
    else:
        r = jax.lax.shift_right_logical(codes, ev["eval_bits"])
        xi = jnp.bitwise_and(codes, (1 << ev["eval_bits"]) - 1)
        sel = coeffs[r]
        xs = jax.lax.shift_left(
            jax.lax.shift_right_logical(xi, ev["sq_trunc"]), ev["sq_trunc"])
        xl = jax.lax.shift_left(
            jax.lax.shift_right_logical(xi, ev["lin_trunc"]), ev["lin_trunc"])
        acc = sel[..., 1] * xl + sel[..., 2]
        if ev["degree"] == 2:
            acc = acc + sel[..., 0] * xs * xs
        tab = jax.lax.shift_right_arithmetic(acc, ev["k"]).astype(jnp.float32)
    rs = tab * (2.0 ** -meta["out_bits"]) * jnp.exp2(-h.astype(jnp.float32))
    return (xf * rs * gamma.astype(jnp.float32)).astype(x.dtype)
