"""Pallas TPU kernel: fused RMSNorm with a table-backed rsqrt.

mean-square -> rsqrt via the generated table over [1, 4) (IEEE exponent
split, odd/even-exponent segment select) -> scale by gamma. One (rows, D)
pass; the rsqrt LUT is the paper-generated artifact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.softmax.kernel import _lut

BLOCK_ROWS = 8


def _rmsnorm_kernel(x_ref, gamma_ref, coef_ref, out_ref, *, meta: dict, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (BLOCK_ROWS, D)
    ms = jnp.mean(x * x, axis=-1, keepdims=True) + eps  # > 0
    bits = jax.lax.bitcast_convert_type(ms, jnp.int32)
    e = jnp.bitwise_and(jax.lax.shift_right_logical(bits, 23), 255) - 127
    mant = jnp.bitwise_and(bits, (1 << 23) - 1)
    b = meta["in_bits"]
    halfcode = 1 << (b - 1)
    rnd = 1 << (23 - (b - 1) - 1)
    frac_code = jnp.clip(jax.lax.shift_right_logical(mant + rnd, 23 - (b - 1)),
                         0, halfcode - 1)
    even = jnp.bitwise_and(e, 1) == 0  # e even -> v = 1.mant in [1,2): segment 0
    codes = jnp.where(even, frac_code, halfcode + frac_code)
    h = jnp.where(even, e // 2, (e - 1) // 2)
    tab = _lut(codes.astype(jnp.int32), coef_ref[...], **meta["eval"]).astype(jnp.float32)
    rs = tab * (2.0 ** -meta["out_bits"]) * jnp.exp2(-h.astype(jnp.float32))
    out_ref[...] = (x * rs * gamma_ref[...].astype(jnp.float32)).astype(out_ref.dtype)


def fused_rmsnorm(x: jax.Array, gamma: jax.Array, coeffs: jax.Array, meta: dict,
                  eps: float = 1e-6, interpret: bool = True) -> jax.Array:
    rows, d = x.shape
    assert rows % BLOCK_ROWS == 0 and d % 128 == 0, x.shape
    nr = coeffs.shape[0]
    kernel = functools.partial(_rmsnorm_kernel, meta=meta, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((nr, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, gamma.reshape(1, d), coeffs)
