"""Pallas TPU kernel: fused RMSNorm with a table-backed rsqrt.

mean-square -> rsqrt via the generated table over [1, 4) (IEEE exponent
split, odd/even-exponent segment select) -> scale by gamma. One (rows, D)
pass; the rsqrt LUT is the paper-generated artifact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.softmax.kernel import _lut

BLOCK_ROWS = 8


def _rmsnorm_body(x, gamma, lut, meta: dict, eps: float, out_dtype):
    """Fused RMSNorm math over an abstract in-kernel rsqrt table read (per-
    table ``_lut`` or library-ROM ``_lut_rom`` closure); one copy of the
    float glue shared by both kernel variants."""
    x = x.astype(jnp.float32)  # (BLOCK_ROWS, D)
    ms = jnp.mean(x * x, axis=-1, keepdims=True) + eps  # > 0
    bits = jax.lax.bitcast_convert_type(ms, jnp.int32)
    e = jnp.bitwise_and(jax.lax.shift_right_logical(bits, 23), 255) - 127
    mant = jnp.bitwise_and(bits, (1 << 23) - 1)
    b = meta["in_bits"]
    halfcode = 1 << (b - 1)
    rnd = 1 << (23 - (b - 1) - 1)
    frac_code = jnp.clip(jax.lax.shift_right_logical(mant + rnd, 23 - (b - 1)),
                         0, halfcode - 1)
    even = jnp.bitwise_and(e, 1) == 0  # e even -> v = 1.mant in [1,2): segment 0
    codes = jnp.where(even, frac_code, halfcode + frac_code)
    h = jnp.where(even, e // 2, (e - 1) // 2)
    tab = lut(codes.astype(jnp.int32)).astype(jnp.float32)
    rs = tab * (2.0 ** -meta["out_bits"]) * jnp.exp2(-h.astype(jnp.float32))
    return (x * rs * gamma.astype(jnp.float32)).astype(out_dtype)


def _rmsnorm_kernel(x_ref, gamma_ref, coef_ref, out_ref, *, meta: dict, eps: float):
    out_ref[...] = _rmsnorm_body(
        x_ref[...], gamma_ref[...],
        lambda c: _lut(c, coef_ref[...], **meta["eval"]),
        meta, eps, out_ref.dtype)


def _rmsnorm_lib_kernel(x_ref, gamma_ref, rom_ref, out_ref, *, r_max: int,
                        meta: dict, eps: float):
    """Library-bound fused RMSNorm: the rsqrt read is a `_lut_rom` gather at
    its static func id against the whole-library ROM operand."""
    from repro.kernels.interp.kernel import _lut_rom

    out_ref[...] = _rmsnorm_body(
        x_ref[...], gamma_ref[...],
        lambda c: _lut_rom(c, rom_ref[...], fid=meta["fid"], r_max=r_max,
                           **meta["eval"]),
        meta, eps, out_ref.dtype)


def fused_rmsnorm_lib(x: jax.Array, gamma: jax.Array, rom: jax.Array,
                      meta: dict, *, r_max: int, eps: float = 1e-6,
                      interpret: bool = True) -> jax.Array:
    """x: (rows, D), rows % BLOCK_ROWS == 0, D % 128 == 0; rom: library
    coefficient ROM flattened to (F * r_max, 3) int32."""
    rows, d = x.shape
    assert rows % BLOCK_ROWS == 0 and d % 128 == 0, x.shape
    n_rows = rom.shape[0]
    kernel = functools.partial(_rmsnorm_lib_kernel, r_max=r_max, meta=meta,
                               eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((n_rows, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, gamma.reshape(1, d), rom)


def fused_rmsnorm(x: jax.Array, gamma: jax.Array, coeffs: jax.Array, meta: dict,
                  eps: float = 1e-6, interpret: bool = True) -> jax.Array:
    rows, d = x.shape
    assert rows % BLOCK_ROWS == 0 and d % 128 == 0, x.shape
    nr = coeffs.shape[0]
    kernel = functools.partial(_rmsnorm_kernel, meta=meta, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((nr, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, gamma.reshape(1, d), coeffs)
