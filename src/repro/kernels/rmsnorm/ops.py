"""Jitted wrapper for the fused approx-RMSNorm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.table import TableDesign
from repro.kernels.rmsnorm.kernel import BLOCK_ROWS, fused_rmsnorm
from repro.kernels.rmsnorm.ref import fused_rmsnorm_ref
from repro.kernels.softmax.ops import _meta
from repro.api import get_table


def approx_rmsnorm_fused(x: jax.Array, gamma: jax.Array,
                         design: TableDesign | None = None, eps: float = 1e-6,
                         use_kernel: bool = True,
                         interpret: bool | None = None) -> jax.Array:
    design = design or get_table("rsqrt")
    coeffs = design.device_coeffs(checked=True)
    meta = _meta(design)
    shape = x.shape
    d = shape[-1]
    rows = x.size // d
    xf = x.reshape(rows, d)
    if not use_kernel:
        return fused_rmsnorm_ref(xf, gamma, coeffs, meta, eps).reshape(shape)
    pad = (-rows) % BLOCK_ROWS
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)), constant_values=1.0)
    interpret = (jax.default_backend() != "tpu") if interpret is None else interpret
    out = fused_rmsnorm(xf, gamma, coeffs, meta, eps=eps, interpret=interpret)
    return out[:rows].reshape(shape)
