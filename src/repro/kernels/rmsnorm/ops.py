"""Jitted wrappers for the fused approx-RMSNorm kernels (per-table design
operand, or the whole-library ROM operand)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.table import TableDesign
from repro.kernels.rmsnorm.kernel import (BLOCK_ROWS, fused_rmsnorm,
                                          fused_rmsnorm_lib)
from repro.kernels.rmsnorm.ref import fused_rmsnorm_lib_ref, fused_rmsnorm_ref
from repro.kernels.softmax.ops import _meta, lib_meta
from repro.api import get_table


def approx_rmsnorm_library(x: jax.Array, gamma: jax.Array, library,
                           eps: float = 1e-6, use_kernel: bool | None = None,
                           interpret: bool | None = None) -> jax.Array:
    """Library-bound fused RMSNorm: the rsqrt table is read in-kernel from
    the compiled library's ROM operand (static func id). ``use_kernel=None``
    picks the Pallas kernel on TPU with 128-lane-aligned features, the
    bit-identical jnp ROM-gather oracle elsewhere."""
    meta = lib_meta(library, "rsqrt")
    shape = x.shape
    d = shape[-1]
    rows = x.size // d
    xf = x.reshape(rows, d)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu" and d % 128 == 0
    if not use_kernel:
        return fused_rmsnorm_lib_ref(xf, gamma, library.coeffs, meta,
                                     eps).reshape(shape)
    pad = (-rows) % BLOCK_ROWS
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)), constant_values=1.0)
    interpret = (jax.default_backend() != "tpu") if interpret is None else interpret
    r_max = library.coeffs.shape[1]
    out = fused_rmsnorm_lib(xf, gamma, library.coeffs.reshape(-1, 3), meta,
                            r_max=r_max, eps=eps, interpret=interpret)
    return out[:rows].reshape(shape)


def approx_rmsnorm_fused(x: jax.Array, gamma: jax.Array,
                         design: TableDesign | None = None, eps: float = 1e-6,
                         use_kernel: bool = True,
                         interpret: bool | None = None) -> jax.Array:
    design = design or get_table("rsqrt")
    coeffs = design.device_coeffs(checked=True)
    meta = _meta(design)
    shape = x.shape
    d = shape[-1]
    rows = x.size // d
    xf = x.reshape(rows, d)
    if not use_kernel:
        return fused_rmsnorm_ref(xf, gamma, coeffs, meta, eps).reshape(shape)
    pad = (-rows) % BLOCK_ROWS
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)), constant_values=1.0)
    interpret = (jax.default_backend() != "tpu") if interpret is None else interpret
    out = fused_rmsnorm(xf, gamma, coeffs, meta, eps=eps, interpret=interpret)
    return out[:rows].reshape(shape)
