"""Schema-versioned run records shared by DSE studies and bench snapshots.

Every ``BENCH_*.json`` perf snapshot and every DSE study/frontier artifact
carries the same envelope: a ``schema`` version plus a ``meta`` block
stamping the seed, jax version and device platform the numbers were
produced under. Before this, snapshots were bare ``{table: rows}`` dicts —
a re-run on different hardware silently overwrote numbers with
incomparable ones and nothing recorded the difference.

``update_snapshot`` is the single writer ``benchmarks/run.py`` and
``launch/dse.py`` go through: it merges fresh tables into the existing
snapshot, restamps ``meta``, preserves a one-time ``*.pre-schema.json``
backup the first time it migrates an unversioned file (so the old numbers
are never silently destroyed), and writes via tmp + atomic rename.
"""
from __future__ import annotations

import datetime
import json
import pathlib
from typing import Any

RECORD_SCHEMA = 1


def run_meta(seed: int | None = None, *, stamp_time: bool = True,
             extra: dict[str, Any] | None = None) -> dict[str, Any]:
    """Provenance block for a snapshot/artifact.

    ``stamp_time=False`` drops the timestamp — required for artifacts with
    a byte-reproducibility contract (the DSE frontier)."""
    import jax  # lazy: keep module import light for non-jax tooling

    meta: dict[str, Any] = {
        "seed": seed,
        "jax": jax.__version__,
        "platform": jax.default_backend(),
    }
    if stamp_time:
        meta["created"] = datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds")
    if extra:
        meta.update(extra)
    return meta


def _migrate_unversioned(path: pathlib.Path, existing) -> dict:
    """Lift a pre-schema snapshot into the versioned envelope, backing the
    original up exactly once. Handles both legacy layouts: the multi-table
    ``{table: rows}`` dict and the bare row *list* a per-table
    ``benchmarks.common.emit`` used to write (wrapped as ``{stem: rows}``)."""
    backup = path.with_name(path.stem + ".pre-schema.json")
    if not backup.exists():
        backup.write_text(json.dumps(existing, indent=1))
    if not isinstance(existing, dict):
        existing = {path.stem: existing}
    return {"schema": RECORD_SCHEMA, "meta": {}, "tables": existing}


def read_snapshot(path: str | pathlib.Path) -> dict[str, Any]:
    """Snapshot tables (empty dict when the file is absent). Accepts the
    versioned envelope and both legacy layouts (bare tables dict / bare
    row list keyed by the file stem)."""
    path = pathlib.Path(path)
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    if not isinstance(data, dict):
        return {path.stem: data}
    if "schema" in data and "tables" in data:
        return dict(data["tables"])
    return dict(data)


def update_snapshot(path: str | pathlib.Path, tables: dict[str, Any], *,
                    seed: int | None = None,
                    meta_extra: dict[str, Any] | None = None
                    ) -> dict[str, Any]:
    """Merge ``tables`` into the snapshot at ``path`` and restamp meta.

    Returns the full written document. Unversioned snapshots are migrated
    (with a ``*.pre-schema.json`` backup) instead of silently overwritten.
    """
    path = pathlib.Path(path)
    if path.exists():
        existing = json.loads(path.read_text())
        if not (isinstance(existing, dict) and "schema" in existing
                and "tables" in existing):
            existing = _migrate_unversioned(path, existing)
        elif existing["schema"] > RECORD_SCHEMA:
            raise ValueError(f"{path}: snapshot schema {existing['schema']} "
                             f"is newer than this code ({RECORD_SCHEMA})")
    else:
        existing = {"schema": RECORD_SCHEMA, "meta": {}, "tables": {}}
    out = {
        "schema": RECORD_SCHEMA,
        "meta": run_meta(seed, extra=meta_extra),
        "tables": {**existing["tables"], **tables},
    }
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(out, indent=1, default=str))
    tmp.replace(path)
    return out
