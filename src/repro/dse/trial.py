"""Trial schema: one full-stack configuration and its journaled verdict.

``TrialParams`` is the unit the DSE layer searches over — everything from
the table's function spec down to the serving engine's dispatch shape. It
is frozen/hashable (usable as a dict key), has a canonical string ``key``
(the journal's dedup key: a resumed study replays a record instead of
re-executing iff the keys match), and round-trips through JSON.

``TrialRecord`` is what the journal stores per trial. Metrics are split by
determinism: ``metrics`` holds only values that are bit-reproducible given
the same code (exact integer area/delay/margin proxies, counter-modeled
throughput) — the frontier artifact is built from these, which is what
makes a killed-and-resumed study's frontier byte-identical to an
uninterrupted run's. Wall-clock noise lives in ``timing`` and never
reaches the frontier.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

from repro.api.config import DEFAULTS, spec_for
from repro.core.funcspec import FunctionSpec, get_spec

TRIAL_SCHEMA = 1


@dataclasses.dataclass(frozen=True)
class TrialParams:
    """One point of the full-stack design space.

    Table axes: ``kind``/``bits``/``out_bits``/``ulp`` (the FunctionSpec),
    ``lookup_bits`` (R), ``degree`` (None = target policy's rule),
    ``target`` (registered Target name), ``engine`` (region backend).
    Serving axes: ``fused`` (one-dispatch tick vs serial oracle),
    ``horizon`` (decode steps per fused dispatch), ``batch`` (slot count),
    ``arch`` (config-zoo architecture the serve probe decodes with).
    ``segmentation`` selects the table layout: ``"uniform"`` (the paper's
    2^R equal regions) or ``"hier"`` (repro.segment's greedy dyadic tree,
    with ``lookup_bits`` as the depth cap).
    """

    kind: str
    lookup_bits: int
    target: str = "asic"
    bits: int | None = None
    out_bits: int | None = None
    ulp: float = 1.0
    degree: int | None = None
    engine: str = "batched"
    fused: bool = True
    horizon: int = 8
    batch: int = 4
    arch: str = "yi_6b"
    segmentation: str = "uniform"

    def spec(self) -> FunctionSpec:
        """Resolve the FunctionSpec exactly as ``ExploreConfig.spec`` does:
        default width inherits the registry's per-kind kwargs; an explicit
        width uses the maker's own defaults."""
        kw: dict = {"ulp": self.ulp}
        if self.out_bits is not None:
            kw["out_bits"] = self.out_bits
        if self.bits is None:
            return spec_for(self.kind, None, **kw)
        return get_spec(self.kind, self.bits, **kw)

    @property
    def resolved_bits(self) -> int:
        return self.bits if self.bits is not None else DEFAULTS[self.kind][0]

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "TrialParams":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(f"unknown TrialParams fields {sorted(unknown)} "
                             f"(newer trial schema?)")
        return cls(**d)

    @property
    def key(self) -> str:
        """Canonical journal key: compact JSON with sorted field names, so
        the key is stable across processes and dataclass field reordering."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))


@dataclasses.dataclass
class TrialRecord:
    """One journaled verdict: parameters + deterministic metrics.

    ``status`` is ``"ok"`` or ``"infeasible"`` (no piecewise polynomial of
    the requested degree exists at this R under this target — a real
    answer worth journaling: resuming must not retry it). ``objectives``
    is the minimized vector the frontier is computed over (None when
    infeasible); ``timing`` holds wall-clock observations excluded from
    the frontier.
    """

    params: TrialParams
    status: str
    metrics: dict[str, Any] = dataclasses.field(default_factory=dict)
    objectives: list[float] | None = None
    timing: dict[str, float] = dataclasses.field(default_factory=dict)
    schema: int = TRIAL_SCHEMA

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    def to_dict(self) -> dict[str, Any]:
        return {
            "schema": self.schema,
            "key": self.params.key,
            "params": self.params.to_dict(),
            "status": self.status,
            "metrics": self.metrics,
            "objectives": self.objectives,
            "timing": self.timing,
        }

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "TrialRecord":
        schema = d.get("schema")
        if schema != TRIAL_SCHEMA:
            raise ValueError(f"trial record schema {schema!r} != "
                             f"{TRIAL_SCHEMA} (migrate the study dir)")
        return cls(params=TrialParams.from_dict(d["params"]),
                   status=d["status"], metrics=dict(d.get("metrics") or {}),
                   objectives=(None if d.get("objectives") is None
                               else [float(x) for x in d["objectives"]]),
                   timing=dict(d.get("timing") or {}), schema=schema)
