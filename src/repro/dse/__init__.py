"""repro.dse — persistent, resumable design-space-exploration studies.

The paper gives *complete* knowledge of each table's piecewise-polynomial
space; this package makes exploration of the **full stack** — table kind,
LUT height, degree, bit widths, hardware target, region engine, fused vs
serial serving, decode horizon, batch — persistent and resumable. A
:class:`Study` evaluates every :class:`TrialParams` of a
:class:`SearchSpace` exactly once, journals each verdict to an append-only
on-disk store (fsync'd, torn-write safe), and emits the multi-objective
Pareto frontier over (area, delay, accuracy margin, decode tokens/sec) as
a committed artifact that ``launch/dse.py check`` regresses against.

Layout (DESIGN.md §13):

  trial.py     TrialParams / TrialRecord — one full-stack configuration
               and its journaled verdict (schema-versioned)
  space.py     SearchSpace grids + the smoke/default presets
  store.py     StudyStore — fsync'd jsonl journal + compacted snapshot
  probe.py     ServeProbe — measured decode tokens/sec via ServeEngine
  study.py     Study — resumable evaluation loop over an Explorer session
  frontier.py  frontier artifact build / save / regression compare
  record.py    schema-versioned snapshot helper shared with benchmarks
"""
from repro.dse.frontier import (build_frontier, compare_frontiers,
                                load_frontier, save_frontier)
from repro.dse.probe import ServeProbe
from repro.dse.record import RECORD_SCHEMA, run_meta, update_snapshot
from repro.dse.space import SearchSpace, default_space, smoke_space
from repro.dse.store import StoreCorrupt, StudyStore
from repro.dse.study import Study
from repro.dse.trial import TrialParams, TrialRecord

__all__ = [
    "RECORD_SCHEMA", "SearchSpace", "ServeProbe", "StoreCorrupt", "Study",
    "StudyStore", "TrialParams", "TrialRecord", "build_frontier",
    "compare_frontiers", "default_space", "load_frontier", "run_meta",
    "save_frontier", "smoke_space", "update_snapshot",
]
