"""Measured decode throughput for DSE trials, via the real serve engine.

Each distinct serving shape — (arch, fused, horizon, batch) — is driven
through an actual :class:`ServeEngine` continuous-batching run (the same
machinery ``benchmarks/decode_fused.py`` measures), serving interp
numerics from the compiled default library. Results are cached per shape:
a study whose table axes fan out over many (kind, R) values pays for each
serving shape once.

Two scoring modes:

  modeled   (default) tokens/sec from the engine's *deterministic* dispatch
            and transfer counters under a fixed per-dispatch cost model.
            The engine genuinely runs — the counters are measurements of
            the program structure — but the score is bit-reproducible
            across runs and hosts, which is what lets a resumed study's
            frontier match an uninterrupted run byte-for-byte and lets CI
            regress against a committed frontier artifact.
  wall      wall-clock tokens/sec (best of ``repeats``), for humans sizing
            real hardware; never used for the frontier contract. In this
            mode the library is compiled at the trial's own LUT height, so
            R reaches the measured datapath.
"""
from __future__ import annotations

import time
from typing import Any

import numpy as np

# deterministic cost model (seconds) for the modeled score: one host->device
# program dispatch vs one device<->host transfer. Absolute values only scale
# the axis; ratios match the dispatch-dominated CPU/TPU serving regime the
# fused tick was built for (DESIGN.md §12).
DISPATCH_COST_S = 1e-4
TRANSFER_COST_S = 2e-5

MODES = ("modeled", "wall", "none")


class ProbeTimeout(RuntimeError):
    """A serve-probe trial exceeded its wall-clock budget (after retry)."""


class ServeProbe:
    """Shared serve-throughput prober for one study.

    ``timeout_s`` bounds one serve run's wall clock: a run that exceeds it
    (a wedged dispatch, a cold compile on a contended host) is treated as a
    transient fault — the probe backs off ``backoff_s`` and retries ONCE,
    and only a second miss raises :class:`ProbeTimeout`. Transient
    exceptions from the engine get the same one-retry treatment. Retries
    are reported through the ``"probe_retries"`` side-channel (popped into
    ``TrialRecord.timing`` by the study, never cached, never in
    ``metrics``): the deterministic metrics split that the frontier
    contract regresses against is identical whether or not a retry
    happened.
    """

    def __init__(self, mode: str = "modeled", *, seed: int = 0,
                 requests: int = 3, prompt_len: int = 8, max_new: int = 8,
                 cache_len: int = 64, repeats: int = 2,
                 timeout_s: float | None = None, backoff_s: float = 0.05):
        if mode not in MODES:
            raise ValueError(f"unknown probe mode {mode!r}; one of {MODES}")
        self.mode = mode
        self.seed, self.repeats = seed, repeats
        self.requests, self.prompt_len = requests, prompt_len
        self.max_new, self.cache_len = max_new, cache_len
        self.timeout_s, self.backoff_s = timeout_s, backoff_s
        self.runs = 0
        self.hits = 0
        self.retries = 0  # lifetime retry count across the study
        self._cache: dict[tuple, dict[str, Any]] = {}
        self._models: dict[str, tuple] = {}  # arch -> (cfg, params)
        self._libraries: dict[Any, Any] = {}

    # -- internals ---------------------------------------------------------
    def _key(self, p) -> tuple:
        key = (p.arch, p.fused, p.horizon, p.batch)
        if self.mode == "wall":
            key += (p.lookup_bits,)  # R reaches the measured ROM
        return key

    def _model(self, arch: str):
        if arch not in self._models:
            import jax

            from repro.configs.base import get_smoke_config
            from repro.models import transformer as tf

            cfg = get_smoke_config(arch).replace(numerics="interp")
            params = tf.init_params(jax.random.key(self.seed), cfg)
            self._models[arch] = (cfg, params)
        return self._models[arch]

    def _library(self, lookup_bits: int | None):
        if lookup_bits not in self._libraries:
            from repro.api import default_explorer

            kw = {} if lookup_bits is None else {"lookup_bits": lookup_bits}
            self._libraries[lookup_bits] = default_explorer().compile(**kw)
        return self._libraries[lookup_bits]

    def _serve_once(self, p) -> tuple[float, dict[str, int], int]:
        from repro.serve.engine import Request, ServeEngine

        cfg, params = self._model(p.arch)
        lib = self._library(p.lookup_bits if self.mode == "wall" else None)
        cache_len = max(self.cache_len, cfg.sliding_window or 0)
        eng = ServeEngine(cfg, params, slots=p.batch, cache_len=cache_len,
                          library=lib, fused=p.fused, horizon=p.horizon)
        rng = np.random.default_rng(self.seed)
        for i in range(self.requests):
            prompt = rng.integers(0, cfg.vocab_size,
                                  self.prompt_len).astype(np.int32)
            eng.submit(Request(i, prompt, max_new=self.max_new))
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        if self.timeout_s is not None and dt > self.timeout_s:
            raise ProbeTimeout(
                f"serve probe for {self._key(p)} took {dt:.3f}s "
                f"(> timeout_s {self.timeout_s}s)")
        return dt, dict(eng.stats), sum(len(r.out) for r in done)

    def _serve_retrying(self, p) -> tuple[int, float, dict[str, int], int]:
        """One serve run with the retry-once-with-backoff policy; returns
        ``(retries, wall_s, stats, tokens)``. The second failure — timeout
        or engine exception — propagates to the study, which records the
        trial as errored rather than wedging the whole run."""
        try:
            return (0, *self._serve_once(p))
        except Exception:
            time.sleep(self.backoff_s)
            self.retries += 1
            return (1, *self._serve_once(p))

    # -- public ------------------------------------------------------------
    def measure(self, p) -> dict[str, Any]:
        """Throughput metrics for trial params ``p`` (cached per shape).

        Returns ``{"tokens_per_s", "dispatches_per_token",
        "transfers_per_token", "throughput_mode"}`` plus (wall mode only)
        the raw wall tokens/sec under ``"wall_tokens_per_s"`` — only the
        deterministic fields belong in ``TrialRecord.metrics``.
        """
        if self.mode == "none":
            return {}
        key = self._key(p)
        if key in self._cache:
            self.hits += 1
            return dict(self._cache[key])
        self.runs += 1
        best_wall = float("inf")
        stats: dict[str, int] = {}
        tokens = 0
        retried = 0
        for _ in range(self.repeats if self.mode == "wall" else 1):
            r, dt, stats, tokens = self._serve_retrying(p)
            retried += r
            best_wall = min(best_wall, dt)
        steps = max(stats.get("decode_steps", 0), 1)
        modeled_t = (stats.get("dispatches", 0) * DISPATCH_COST_S
                     + stats.get("transfers", 0) * TRANSFER_COST_S)
        out: dict[str, Any] = {
            "throughput_mode": self.mode,
            "dispatches_per_token": stats.get("dispatches", 0) / steps,
            "transfers_per_token": stats.get("transfers", 0) / steps,
        }
        if self.mode == "modeled":
            out["tokens_per_s"] = steps / max(modeled_t, 1e-12)
        else:
            out["tokens_per_s"] = tokens / max(best_wall, 1e-12)
            out["wall_tokens_per_s"] = out["tokens_per_s"]
        # the cache holds only the deterministic fields; a retry is a
        # wall-clock accident of THIS run and is reported, not replayed
        self._cache[key] = out
        out = dict(out)
        if retried:
            out["probe_retries"] = retried
        return out

    @property
    def stats(self) -> dict[str, int]:
        return {"runs": self.runs, "hits": self.hits,
                "retries": self.retries}
