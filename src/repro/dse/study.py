"""The Study: a persistent, resumable sweep over the full-stack space.

A study owns one :class:`StudyStore` (journal + snapshot under its
directory), one :class:`Explorer` session per region engine it touches,
and one :class:`ServeProbe`. ``run()`` walks the search space in its
deterministic order, *replays* every trial whose key is already journaled
(zero recomputation — the ``replayed``/``executed`` counters are the
resume contract the tests assert), batches the cache-missing trials'
envelope probes into one fleet program (``Explorer.prime_envelopes``),
evaluates the remainder, and journals each verdict durably before moving
on. Killing the process at any point loses at most the in-flight trial.

Objectives (all minimized; frontier grouped per target — see frontier.py):

  area, delay           the trial target's proxy units for the chosen
                        design at this (spec, R)
  neg_accuracy_margin   minus the worst-case slack, in output ULPs, between
                        the certified design and its §II error envelope —
                        more margin survives downstream quantization
  neg_tokens_per_s      minus the serve probe's decode throughput
                        (absent when the probe mode is "none")
"""
from __future__ import annotations

import json
import os
import pathlib
import time
from typing import Any

import numpy as np

from repro.api.config import ExploreConfig
from repro.api.explorer import Explorer
from repro.core.funcspec import FunctionSpec
from repro.core.table import TableDesign
from repro.dse.frontier import build_frontier, save_frontier
from repro.dse.probe import ServeProbe
from repro.dse.record import run_meta
from repro.dse.space import SearchSpace
from repro.dse.store import StudyStore
from repro.dse.trial import TrialParams, TrialRecord

STUDY_SCHEMA = 1
STUDY_FILE = "study.json"
FRONTIER_FILE = "frontier.json"

OBJECTIVES_PROXY = ("area", "delay", "neg_accuracy_margin")
OBJECTIVES_FULL = OBJECTIVES_PROXY + ("neg_tokens_per_s",)


def accuracy_margin_ulp(design: TableDesign, spec: FunctionSpec) -> int:
    """Worst-case slack (output ULPs) between the design and its §II
    envelope: ``min over all inputs of min(y - L, U - y)``. Exhaustive and
    exact (integer arithmetic), like ``TableDesign.verify``; >= 0 for any
    verified design, and larger means the design survives more downstream
    perturbation before violating the paper's error bound."""
    lo, hi = spec.bound_arrays()
    codes = np.arange(1 << design.in_bits, dtype=np.int64)
    y = design.eval_int(codes)
    return int(np.minimum(y - lo, hi - y).min())


class Study:
    """One resumable DSE study rooted at a directory.

    Construct with a ``space`` to create (or extend) a study; construct
    with ``space=None`` to resume purely from the saved ``study.json``.
    ``measure`` (probe mode: modeled/wall/none) and ``seed`` default to
    the saved values on resume; changing the measure of an existing study
    is refused — it would change the objective axes out from under the
    journaled records.
    """

    def __init__(self, root: str | pathlib.Path, space: SearchSpace | None = None,
                 *, measure: str | None = None, seed: int | None = None,
                 explore: ExploreConfig | None = None,
                 probe: ServeProbe | None = None, name: str | None = None):
        self.root = pathlib.Path(root)
        self.store = StudyStore(self.root)
        saved = self._load_study_file()
        if saved is not None:
            if measure is not None and measure != saved["measure"]:
                raise ValueError(
                    f"study {self.root} was created with measure="
                    f"{saved['measure']!r}; changing it to {measure!r} would "
                    f"change the objective axes under the journaled trials")
            measure = saved["measure"]
            seed = saved["seed"] if seed is None else seed
            if space is None:
                space = SearchSpace.from_dict(saved["space"])
            name = name or saved.get("name")
        elif space is None:
            raise ValueError(f"no study at {self.root} and no space given")
        self.space = space
        self.measure = measure or "modeled"
        self.seed = 0 if seed is None else seed
        self.name = name or self.root.name
        self.objectives = list(OBJECTIVES_PROXY if self.measure == "none"
                               else OBJECTIVES_FULL)
        self.probe = probe or ServeProbe(self.measure, seed=self.seed)
        self._explore_cfg = explore or ExploreConfig()
        self._explorers: dict[str, Explorer] = {}
        self._specs: dict[tuple, FunctionSpec] = {}
        self.stats = {"executed": 0, "replayed": 0, "infeasible": 0}
        if saved is None:
            self._write_study_file()

    # -- persistence of the study header -----------------------------------
    def _study_path(self) -> pathlib.Path:
        return self.root / STUDY_FILE

    def _load_study_file(self) -> dict[str, Any] | None:
        path = self._study_path()
        if not path.exists():
            return None
        doc = json.loads(path.read_text())
        if doc.get("schema") != STUDY_SCHEMA:
            raise ValueError(f"{path}: study schema {doc.get('schema')!r} "
                             f"!= {STUDY_SCHEMA}")
        return doc

    def _write_study_file(self) -> None:
        self.root.mkdir(parents=True, exist_ok=True)
        doc = {
            "schema": STUDY_SCHEMA,
            "name": self.name,
            "measure": self.measure,
            "seed": self.seed,
            "objectives": self.objectives,
            "space": self.space.to_dict(),
            "meta": run_meta(self.seed),
        }
        tmp = self._study_path().with_suffix(".json.tmp")
        tmp.write_text(json.dumps(doc, indent=1, sort_keys=True))
        tmp.replace(self._study_path())

    # -- lifecycle ---------------------------------------------------------
    def __enter__(self) -> "Study":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        self.store.close()
        for ex in self._explorers.values():
            ex.close()
        self._explorers.clear()

    # -- evaluation machinery ----------------------------------------------
    def _explorer(self, engine: str) -> Explorer:
        if engine not in self._explorers:
            import dataclasses

            cfg = dataclasses.replace(self._explore_cfg, engine=engine)
            self._explorers[engine] = Explorer(cfg)
        return self._explorers[engine]

    def _spec(self, p: TrialParams) -> FunctionSpec:
        key = (p.kind, p.bits, p.out_bits, p.ulp)
        if key not in self._specs:
            self._specs[key] = p.spec()
        return self._specs[key]

    def _evaluate(self, p: TrialParams) -> TrialRecord:
        spec = self._spec(p)
        t0 = time.perf_counter()
        if p.segmentation == "hier":
            return self._evaluate_hier(p, spec, t0)
        ex = self._explorer(p.engine)
        entry = ex.explore_r(spec, p.lookup_bits, target=p.target,
                             degree=p.degree)
        if entry is None:
            return TrialRecord(p, "infeasible",
                               timing={"eval_s": time.perf_counter() - t0})
        margin = accuracy_margin_ulp(entry.design, spec)
        metrics: dict[str, Any] = {
            "area": float(entry.area),
            "delay": float(entry.delay),
            "accuracy_margin": margin,
            "degree": entry.design.degree,
            "k": entry.report.k,
        }
        timing: dict[str, float] = {"explore_s": entry.runtime_s}
        served = self.probe.measure(p)
        wall = served.pop("wall_tokens_per_s", None)
        if wall is not None:
            timing["wall_tokens_per_s"] = wall
        retries = served.pop("probe_retries", None)
        if retries:  # wall-clock accident, not part of the metrics contract
            timing["retries"] = int(retries)
        metrics.update(served)
        objectives = [metrics["area"], metrics["delay"], -float(margin)]
        if self.measure != "none":
            objectives.append(-float(metrics["tokens_per_s"]))
        timing["eval_s"] = time.perf_counter() - t0
        return TrialRecord(p, "ok", metrics=metrics, objectives=objectives,
                           timing=timing)

    def _evaluate_hier(self, p: TrialParams, spec: FunctionSpec,
                       t0: float) -> TrialRecord:
        """Non-uniform trial: the greedy segmenter with ``lookup_bits`` as
        the depth cap, costed by the segment-aware estimator (uniform cost
        model over stored rows + the target's segment decoder)."""
        from repro.segment import estimate_segmented, explore_segmented

        design = explore_segmented(spec, max_depth=p.lookup_bits,
                                   degree=p.degree, engine=p.engine)
        if design is None:
            return TrialRecord(p, "infeasible",
                               timing={"eval_s": time.perf_counter() - t0})
        ad = estimate_segmented(design, p.target)
        margin = accuracy_margin_ulp(design, spec)
        metrics: dict[str, Any] = {
            "area": float(ad.area),
            "delay": float(ad.delay),
            "accuracy_margin": margin,
            "degree": design.degree,
            "k": design.k,
            "rows": design.rows_used,
            "leaves": design.n_leaves,
        }
        timing: dict[str, float] = {}
        served = self.probe.measure(p)
        wall = served.pop("wall_tokens_per_s", None)
        if wall is not None:
            timing["wall_tokens_per_s"] = wall
        retries = served.pop("probe_retries", None)
        if retries:
            timing["retries"] = int(retries)
        metrics.update(served)
        objectives = [metrics["area"], metrics["delay"], -float(margin)]
        if self.measure != "none":
            objectives.append(-float(metrics["tokens_per_s"]))
        timing["eval_s"] = time.perf_counter() - t0
        return TrialRecord(p, "ok", metrics=metrics, objectives=objectives,
                           timing=timing)

    # -- the resumable loop ------------------------------------------------
    def run(self, max_trials: int | None = None,
            compact: bool = False) -> dict[str, TrialRecord]:
        """Evaluate every not-yet-journaled trial (up to ``max_trials``).

        Returns the full record map (replayed + fresh). Writes the frontier
        artifact whenever the space is fully evaluated; ``compact`` folds
        the journal into the snapshot afterwards.
        """
        records = self.store.load()
        todo: list[TrialParams] = []
        for p in self.space.trials():
            if p.key in records:
                self.stats["replayed"] += 1
            else:
                todo.append(p)
        remaining = len(todo)
        if max_trials is not None:
            todo = todo[:max_trials]
        # one fleet program per engine primes every cold trial's envelopes
        # (hier trials walk their own segmentations — nothing to prime)
        by_engine: dict[str, list] = {}
        for p in todo:
            if p.segmentation == "hier":
                continue
            by_engine.setdefault(p.engine, []).append(
                (self._spec(p), p.lookup_bits))
        for engine, pairs in by_engine.items():
            self._explorer(engine).prime_envelopes(pairs)
        for p in todo:
            rec = self._evaluate(p)
            self.store.append(rec)
            records[p.key] = rec
            self.stats["executed"] += 1
            if not rec.ok:
                self.stats["infeasible"] += 1
        if len(todo) == remaining:  # space fully evaluated
            self.write_frontier(records)
            if compact:
                self.store.compact()
        return records

    # -- frontier ----------------------------------------------------------
    def frontier(self, records: dict[str, TrialRecord] | None = None
                 ) -> dict[str, Any]:
        return build_frontier(records if records is not None
                              else self.store.load(), self.objectives)

    def frontier_path(self) -> pathlib.Path:
        return self.root / FRONTIER_FILE

    def write_frontier(self, records: dict[str, TrialRecord] | None = None
                       ) -> pathlib.Path:
        """Emit ``frontier.json`` (deterministic bytes: no timestamp)."""
        meta = run_meta(self.seed, stamp_time=False,
                        extra={"measure": self.measure, "study": self.name})
        return save_frontier(self.frontier_path(),
                             self.frontier(records), meta)

    def summary(self) -> dict[str, Any]:
        """One flat row for reports / the BENCH_6 snapshot."""
        records = self.store.load()
        front = self.frontier(records)
        done = [r for r in records.values() if r.ok]
        return {
            "study": self.name,
            "measure": self.measure,
            "trials_total": len(self.space),
            "trials_recorded": len(records),
            "trials_ok": len(done),
            "trials_infeasible": len(records) - len(done),
            "executed_this_run": self.stats["executed"],
            "replayed_this_run": self.stats["replayed"],
            "frontier_points": {t: len(pts)
                                for t, pts in front["groups"].items()},
            "probe_runs": self.probe.runs,
            "probe_cache_hits": self.probe.hits,
        }
