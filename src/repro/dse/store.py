"""Torn-write-safe study persistence: fsync'd jsonl journal + snapshot.

Discipline (the shared :mod:`repro.util.journal` machinery, same as
``InterpLibrary.save`` and the serve-state journal — DESIGN.md §10/§14):
every journal append is one ``\\n``-terminated JSON line flushed and
``fsync``'d before the trial is considered durable; compaction writes the
full record set to ``snapshot.json`` via tmp + fsync + atomic rename and
only then resets the journal. Crash anywhere leaves a recoverable store:

  * killed mid-append → the torn final line is detected (no newline, or
    JSON parse failure on the *last* line only) and dropped; every earlier
    record survives. A torn line mid-file is real corruption and raises
    :class:`StoreCorrupt` instead of silently losing the tail.
  * killed between snapshot rename and journal reset → records exist in
    both; load dedups by trial key (first wins — re-journaled records are
    bit-identical by the determinism contract in trial.py).
"""
from __future__ import annotations

import json
import pathlib
from typing import Any

from repro.dse.trial import TrialRecord
from repro.util.journal import (JournalCorrupt, JournalWriter,
                                atomic_write_text, read_journal)

JOURNAL = "journal.jsonl"
SNAPSHOT = "snapshot.json"
SNAPSHOT_SCHEMA = 1


class StoreCorrupt(JournalCorrupt):
    """The on-disk study store is damaged beyond a torn tail."""


class StudyStore:
    """Append-only trial store under one study directory."""

    def __init__(self, root: str | pathlib.Path):
        self.root = pathlib.Path(root)
        self.journal_path = self.root / JOURNAL
        self.snapshot_path = self.root / SNAPSHOT
        self._writer = JournalWriter(self.journal_path)
        self.torn_tail_drops = 0  # incomplete final lines discarded on load

    # -- lifecycle ---------------------------------------------------------
    def __enter__(self) -> "StudyStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        self._writer.close()

    # -- append ------------------------------------------------------------
    def append(self, record: TrialRecord) -> None:
        """Durably journal one record: write line, flush, fsync."""
        self.root.mkdir(parents=True, exist_ok=True)
        self._writer.append(record.to_dict())

    # -- load --------------------------------------------------------------
    def _journal_records(self) -> list[dict[str, Any]]:
        records, dropped = read_journal(self.journal_path, corrupt=StoreCorrupt)
        self.torn_tail_drops += dropped
        return records

    def _snapshot_records(self) -> list[dict[str, Any]]:
        if not self.snapshot_path.exists():
            return []
        try:
            snap = json.loads(self.snapshot_path.read_text(encoding="utf-8"))
        except json.JSONDecodeError as e:
            # snapshots are written atomically (tmp + rename): a damaged one
            # was never a valid snapshot, not a torn write
            raise StoreCorrupt(f"{self.snapshot_path}: undecodable") from e
        if snap.get("schema") != SNAPSHOT_SCHEMA:
            raise StoreCorrupt(f"{self.snapshot_path}: schema "
                               f"{snap.get('schema')!r} != {SNAPSHOT_SCHEMA}")
        return list(snap.get("records") or [])

    def load(self) -> dict[str, TrialRecord]:
        """All durable records, keyed by trial key (snapshot, then journal;
        first occurrence wins — see the crash-window note above)."""
        out: dict[str, TrialRecord] = {}
        for d in self._snapshot_records() + self._journal_records():
            rec = TrialRecord.from_dict(d)
            out.setdefault(rec.params.key, rec)
        return out

    # -- compaction --------------------------------------------------------
    def compact(self) -> None:
        """Fold the journal into ``snapshot.json`` and reset the journal.

        Write order is crash-safe: snapshot tmp → fsync → rename (the new
        snapshot is durable before the journal shrinks), then the journal
        is reset via an atomic empty-file rename. A crash between the two
        leaves duplicates, which ``load`` dedups.
        """
        records = self.load()
        self.close()  # the append handle's offset dies with the old journal
        self.root.mkdir(parents=True, exist_ok=True)
        snap = {"schema": SNAPSHOT_SCHEMA,
                "records": [r.to_dict() for r in records.values()]}
        atomic_write_text(self.snapshot_path,
                          json.dumps(snap, sort_keys=True,
                                     separators=(",", ":")))
        jtmp = self.journal_path.with_suffix(".jsonl.tmp")
        jtmp.write_text("")
        jtmp.replace(self.journal_path)
