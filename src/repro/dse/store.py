"""Torn-write-safe study persistence: fsync'd jsonl journal + snapshot.

Discipline (same as ``InterpLibrary.save``, DESIGN.md §10): every journal
append is one ``\\n``-terminated JSON line flushed and ``fsync``'d before
the trial is considered durable; compaction writes the full record set to
``snapshot.json`` via tmp + fsync + atomic rename and only then resets the
journal. Crash anywhere leaves a recoverable store:

  * killed mid-append → the torn final line is detected (no newline, or
    JSON parse failure on the *last* line only) and dropped; every earlier
    record survives. A torn line mid-file is real corruption and raises
    :class:`StoreCorrupt` instead of silently losing the tail.
  * killed between snapshot rename and journal reset → records exist in
    both; load dedups by trial key (first wins — re-journaled records are
    bit-identical by the determinism contract in trial.py).
"""
from __future__ import annotations

import json
import os
import pathlib
from typing import Any

from repro.dse.trial import TrialRecord

JOURNAL = "journal.jsonl"
SNAPSHOT = "snapshot.json"
SNAPSHOT_SCHEMA = 1


class StoreCorrupt(RuntimeError):
    """The on-disk study store is damaged beyond a torn tail."""


class StudyStore:
    """Append-only trial store under one study directory."""

    def __init__(self, root: str | pathlib.Path):
        self.root = pathlib.Path(root)
        self.journal_path = self.root / JOURNAL
        self.snapshot_path = self.root / SNAPSHOT
        self._fh = None  # lazily opened append handle
        self.torn_tail_drops = 0  # incomplete final lines discarded on load

    # -- lifecycle ---------------------------------------------------------
    def __enter__(self) -> "StudyStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- append ------------------------------------------------------------
    def _trim_torn_tail(self) -> None:
        """Repair an unterminated journal tail before appending: a complete
        record missing only its newline gets terminated; a torn fragment is
        truncated away (it was never durable — the append that wrote it
        died before fsync returned)."""
        if not self.journal_path.exists():
            return
        with open(self.journal_path, "rb+") as f:
            data = f.read()
            if not data or data.endswith(b"\n"):
                return
            cut = data.rfind(b"\n") + 1
            try:
                json.loads(data[cut:].decode("utf-8"))
            except (UnicodeDecodeError, json.JSONDecodeError):
                f.truncate(cut)
            else:
                f.write(b"\n")

    def append(self, record: TrialRecord) -> None:
        """Durably journal one record: write line, flush, fsync."""
        if self._fh is None:
            self.root.mkdir(parents=True, exist_ok=True)
            self._trim_torn_tail()
            self._fh = open(self.journal_path, "a", encoding="utf-8")
        line = json.dumps(record.to_dict(), sort_keys=True,
                          separators=(",", ":"))
        self._fh.write(line + "\n")
        self._fh.flush()
        os.fsync(self._fh.fileno())

    # -- load --------------------------------------------------------------
    def _journal_records(self) -> list[dict[str, Any]]:
        if not self.journal_path.exists():
            return []
        raw = self.journal_path.read_text(encoding="utf-8")
        if not raw:
            return []
        lines = raw.split("\n")
        if lines[-1] == "":
            lines.pop()  # the usual case: journal ends with a newline
        out = []
        last = len(lines) - 1
        for i, line in enumerate(lines):
            if line == "":
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError as e:
                if i == last:
                    # the final line only: a torn append (with or without
                    # its newline) is recoverable tail damage
                    self.torn_tail_drops += 1
                    continue
                raise StoreCorrupt(
                    f"{self.journal_path}: undecodable journal line "
                    f"{i + 1} (not the tail — refusing to drop committed "
                    f"trials)") from e
        return out

    def _snapshot_records(self) -> list[dict[str, Any]]:
        if not self.snapshot_path.exists():
            return []
        try:
            snap = json.loads(self.snapshot_path.read_text(encoding="utf-8"))
        except json.JSONDecodeError as e:
            # snapshots are written atomically (tmp + rename): a damaged one
            # was never a valid snapshot, not a torn write
            raise StoreCorrupt(f"{self.snapshot_path}: undecodable") from e
        if snap.get("schema") != SNAPSHOT_SCHEMA:
            raise StoreCorrupt(f"{self.snapshot_path}: schema "
                               f"{snap.get('schema')!r} != {SNAPSHOT_SCHEMA}")
        return list(snap.get("records") or [])

    def load(self) -> dict[str, TrialRecord]:
        """All durable records, keyed by trial key (snapshot, then journal;
        first occurrence wins — see the crash-window note above)."""
        out: dict[str, TrialRecord] = {}
        for d in self._snapshot_records() + self._journal_records():
            rec = TrialRecord.from_dict(d)
            out.setdefault(rec.params.key, rec)
        return out

    # -- compaction --------------------------------------------------------
    def compact(self) -> None:
        """Fold the journal into ``snapshot.json`` and reset the journal.

        Write order is crash-safe: snapshot tmp → fsync → rename (the new
        snapshot is durable before the journal shrinks), then the journal
        is reset via an atomic empty-file rename. A crash between the two
        leaves duplicates, which ``load`` dedups.
        """
        records = self.load()
        self.close()  # the append handle's offset dies with the old journal
        self.root.mkdir(parents=True, exist_ok=True)
        snap = {"schema": SNAPSHOT_SCHEMA,
                "records": [r.to_dict() for r in records.values()]}
        tmp = self.snapshot_path.with_suffix(".json.tmp")
        with open(tmp, "w", encoding="utf-8") as f:
            f.write(json.dumps(snap, sort_keys=True, separators=(",", ":")))
            f.flush()
            os.fsync(f.fileno())
        tmp.replace(self.snapshot_path)
        jtmp = self.journal_path.with_suffix(".jsonl.tmp")
        jtmp.write_text("")
        jtmp.replace(self.journal_path)
