"""Search spaces: declarative grids over :class:`TrialParams`.

A ``SearchSpace`` is the cross product of per-axis value tuples, enumerated
in a deterministic order (axis order below, values in the given order) —
the enumeration order is part of the resume contract: a resumed study walks
the same sequence and skips journaled keys, so "zero re-executed trials"
is checkable by counter.

Two presets ship: :func:`smoke_space` (the CI dse-smoke study — small
enough to run twice per CI job) and :func:`default_space` (the committed-
frontier study over the whole table manifest).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Iterator

from repro.api.config import DEFAULTS
from repro.dse.trial import TrialParams

SPACE_SCHEMA = 1


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Axis grids; every field mirrors a :class:`TrialParams` field."""

    kinds: tuple[str, ...] = ("recip",)
    lookup_bits: tuple[int, ...] = (5, 6, 7)
    targets: tuple[str, ...] = ("asic",)
    bits: tuple[int | None, ...] = (None,)
    out_bits: tuple[int | None, ...] = (None,)
    ulps: tuple[float, ...] = (1.0,)
    degrees: tuple[int | None, ...] = (None,)
    engines: tuple[str, ...] = ("batched",)
    fused: tuple[bool, ...] = (True,)
    horizons: tuple[int, ...] = (8,)
    batches: tuple[int, ...] = (4,)
    arch: str = "yi_6b"
    segmentations: tuple[str, ...] = ("uniform",)

    def __len__(self) -> int:
        n = 1
        for axis in (self.kinds, self.lookup_bits, self.targets, self.bits,
                     self.out_bits, self.ulps, self.degrees, self.engines,
                     self.fused, self.horizons, self.batches,
                     self.segmentations):
            n *= len(axis)
        return n

    def trials(self) -> Iterator[TrialParams]:
        """Deterministic enumeration (itertools.product in axis order)."""
        for (kind, r, target, bits, out_bits, ulp, degree, engine, fused,
             horizon, batch, segmentation) in itertools.product(
                self.kinds, self.lookup_bits, self.targets, self.bits,
                self.out_bits, self.ulps, self.degrees, self.engines,
                self.fused, self.horizons, self.batches, self.segmentations):
            yield TrialParams(kind=kind, lookup_bits=r, target=target,
                              bits=bits, out_bits=out_bits, ulp=ulp,
                              degree=degree, engine=engine, fused=fused,
                              horizon=horizon, batch=batch, arch=self.arch,
                              segmentation=segmentation)

    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["schema"] = SPACE_SCHEMA
        return d

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "SearchSpace":
        d = dict(d)
        schema = d.pop("schema", SPACE_SCHEMA)
        if schema != SPACE_SCHEMA:
            raise ValueError(f"search-space schema {schema!r} != {SPACE_SCHEMA}")
        tuple_fields = {f.name for f in dataclasses.fields(cls)
                        if f.name != "arch"}
        return cls(**{k: tuple(v) if k in tuple_fields else v
                      for k, v in d.items()})


def smoke_space() -> SearchSpace:
    """The CI study: 2 kinds x 2 heights x 2 targets x fused/serial = 16
    trials, 2 distinct serve-probe keys. Small enough to run fresh + resumed
    in one CI job, big enough that every objective axis varies."""
    return SearchSpace(kinds=("recip", "exp2neg"), lookup_bits=(5, 6),
                       targets=("asic", "pallas-tpu"), fused=(False, True),
                       horizons=(4,), batches=(2,), arch="yi_6b")


def default_space() -> SearchSpace:
    """The committed-frontier study: every library kind, the useful height
    band around the registry defaults, all built-in targets, both serve
    paths and two dispatch shapes."""
    return SearchSpace(kinds=tuple(sorted(DEFAULTS)), lookup_bits=(4, 5, 6, 7, 8),
                       targets=("asic", "fpga-lut", "pallas-tpu"),
                       fused=(False, True), horizons=(8,), batches=(2, 8),
                       arch="yi_6b")


def segment_space() -> SearchSpace:
    """The study-8 increment: the four activation/transcendental kinds the
    segment subsystem most benefits, both layouts per point, every target.
    A deterministic chunk of the full product — small enough to regenerate
    from scratch, big enough that uniform and hier compete on every
    frontier group."""
    return SearchSpace(kinds=("exp2neg", "recip", "sigmoid", "tanh"),
                       lookup_bits=(5, 6),
                       targets=("asic", "fpga-lut", "pallas-tpu"),
                       fused=(True,), horizons=(8,), batches=(2,),
                       arch="yi_6b", segmentations=("uniform", "hier"))


PRESETS = {"smoke": smoke_space, "default": default_space,
           "segment": segment_space}
