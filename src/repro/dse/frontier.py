"""Frontier artifact: build, save, and regression-compare Pareto fronts.

The frontier is grouped **per target**: area/delay units are a target's own
(NAND2-eq/FO4 for asic, LUTs/levels for fpga-lut, VMEM bytes/product bits
for pallas-tpu), so cross-target domination would compare incommensurable
units. Within a group, every completed trial's objective vector — built by
:class:`repro.dse.study.Study` as ``(area, delay, -accuracy_margin,
-tokens_per_s)``, all minimized — competes, and the non-dominated set (via
:func:`repro.core.pareto.pareto_indices`, the same code the per-spec
R-sweep frontier uses) is serialized with deterministic JSON so the
artifact is byte-reproducible.

``compare_frontiers`` is the regression oracle: the fresh study must
dominate-or-match every committed frontier point. New points beyond the
committed front are improvements, not errors; a committed point no fresh
trial can match means the stack lost ground and ``launch/dse.py check``
exits nonzero.
"""
from __future__ import annotations

import json
import os
import pathlib
from typing import Any, Iterable

from repro.core.pareto import dominates, pareto_indices

FRONTIER_SCHEMA = 1


def build_frontier(records: Iterable, objectives: list[str]) -> dict[str, Any]:
    """Per-target Pareto groups from completed :class:`TrialRecord`s.

    ``records`` may be the dict ``StudyStore.load`` returns or any iterable
    of records; infeasible trials carry no objective vector and only count
    toward the totals.
    """
    recs = list(records.values() if isinstance(records, dict) else records)
    by_target: dict[str, list] = {}
    infeasible = 0
    for r in recs:
        if not r.ok or r.objectives is None:
            infeasible += 1
            continue
        if len(r.objectives) != len(objectives):
            raise ValueError(
                f"record {r.params.key} has {len(r.objectives)} objectives, "
                f"study defines {len(objectives)}")
        by_target.setdefault(r.params.target, []).append(r)
    groups: dict[str, list[dict[str, Any]]] = {}
    for target in sorted(by_target):
        grp = by_target[target]
        idx = pareto_indices([r.objectives for r in grp])
        groups[target] = [{
            "params": grp[i].params.to_dict(),
            "metrics": grp[i].metrics,
            "objectives": grp[i].objectives,
        } for i in idx]
    return {
        "schema": FRONTIER_SCHEMA,
        "objectives": list(objectives),
        "trials": {"completed": len(recs) - infeasible,
                   "infeasible": infeasible},
        "groups": groups,
    }


def save_frontier(path: str | pathlib.Path, frontier: dict[str, Any],
                  meta: dict[str, Any] | None = None) -> pathlib.Path:
    """Write the artifact deterministically (sorted keys, tmp + rename).

    ``meta`` must itself be deterministic for the byte-identity contract —
    use ``run_meta(stamp_time=False)``.
    """
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    doc = dict(frontier)
    if meta is not None:
        doc["meta"] = meta
    tmp = path.with_suffix(".json.tmp")
    with open(tmp, "w", encoding="utf-8") as f:
        f.write(json.dumps(doc, indent=1, sort_keys=True))
        f.flush()
        os.fsync(f.fileno())
    tmp.replace(path)
    return path


def load_frontier(path: str | pathlib.Path) -> dict[str, Any]:
    doc = json.loads(pathlib.Path(path).read_text())
    if doc.get("schema") != FRONTIER_SCHEMA:
        raise ValueError(f"{path}: frontier schema {doc.get('schema')!r} "
                         f"!= {FRONTIER_SCHEMA}")
    return doc


def _param_axes(doc: dict[str, Any]) -> set[str]:
    """Union of trial-parameter field names across a frontier's points."""
    axes: set[str] = set()
    for pts in doc.get("groups", {}).values():
        for p in pts:
            axes.update(p.get("params", {}))
    return axes


def compare_frontiers(fresh: dict[str, Any], committed: dict[str, Any]
                      ) -> list[str]:
    """Regressions of ``fresh`` against ``committed`` (empty = healthy).

    A committed frontier point regresses when no fresh point in the same
    target group weakly dominates its objective vector. Meta blocks and
    extra fresh points are ignored — the committed artifact is a floor,
    not an exact expectation. Trial-parameter axes may *grow*: a fresh
    study whose params are a superset of the committed ones (a new
    TrialParams field with a default, e.g. ``segmentation``) compares
    cleanly against an older artifact; only a *vanished* committed axis is
    flagged, since the fresh study can then no longer express the
    committed points.
    """
    problems: list[str] = []
    if fresh.get("objectives") != committed.get("objectives"):
        return [f"objective axes changed: fresh {fresh.get('objectives')} "
                f"vs committed {committed.get('objectives')} — "
                f"regenerate the committed artifact"]
    lost_axes = _param_axes(committed) - _param_axes(fresh)
    if lost_axes and fresh.get("groups"):
        return [f"trial axes {sorted(lost_axes)} present in the committed "
                f"frontier are missing from the fresh study — the fresh "
                f"study cannot express the committed points"]
    for target, committed_pts in committed.get("groups", {}).items():
        fresh_pts = fresh.get("groups", {}).get(target)
        if not fresh_pts:
            problems.append(f"[{target}] group vanished from the fresh study")
            continue
        for c in committed_pts:
            if not any(dominates(f["objectives"], c["objectives"])
                       for f in fresh_pts):
                problems.append(
                    f"[{target}] committed point {c['objectives']} "
                    f"(params {c['params'].get('kind')}/R"
                    f"{c['params'].get('lookup_bits')}) is no longer "
                    f"attained by any fresh frontier point")
    return problems
