"""Paper Figs 2-3: area-delay profile across LUT heights.

For each function we sweep all feasible LUB values and report the proxy
area/delay per point (the paper's Fig 3 shows 10/16-bit log2; Fig 2 the
23-bit reciprocal profile). The "best LUB is metric-dependent" observation
is reproduced by reporting both the min-area and min-delay choices.
"""
from __future__ import annotations

from benchmarks.common import QUICK, emit
from repro.api import Explorer, get_spec

CASES_FULL = [("log2", 10, {"out_bits": 11}), ("log2", 16, {"out_bits": 17}),
              ("recip", 12, {})]
CASES_QUICK = [("log2", 10, {"out_bits": 11}), ("recip", 10, {})]


def run() -> list[dict]:
    rows = []
    ex = Explorer()
    for kind, bits, kw in (CASES_QUICK if QUICK else CASES_FULL):
        spec = get_spec(kind, bits, **kw)
        results = ex.explore(spec).entries
        for g in results:
            d = g.design
            rows.append({
                "function": f"{kind}{bits}", "LUB": d.lookup_bits,
                "degree": "lin" if d.degree == 1 else "quad",
                "k": d.k, "lut_widths": str(d.lut_widths),
                "area": round(g.area, 0), "delay": round(g.delay, 2),
                "area_x_delay": round(g.area_delay, 0),
            })
        if results:
            best_a = min(results, key=lambda g: g.area)
            best_d = min(results, key=lambda g: g.delay)
            best_ad = min(results, key=lambda g: g.area_delay)
            rows.append({
                "function": f"{kind}{bits}", "LUB": "choice",
                "degree": "", "k": "", "lut_widths": "",
                "area": f"minA@R{best_a.design.lookup_bits}",
                "delay": f"minD@R{best_d.design.lookup_bits}",
                "area_x_delay": f"minAD@R{best_ad.design.lookup_bits}",
            })
    emit("fig3_lub_sweep", rows)
    return rows


if __name__ == "__main__":
    run()
