"""Paper §II-A scaling claims:

  1. runtime vs lookup bits R — "empirical results for a 16 bit design
     suggest the runtime is O(R^-3)": more regions means narrower regions,
     so the quadratic per-region searches shrink faster than region count
     grows. We fit the log-log slope on the seed backend (pooled +
     Claim II.1 scalar search — the paper's single-threaded PyPy generator)
     and report the batched region engine alongside with a
     speedup-vs-seed column.
  2. runtime vs input bits at fixed relative R — "scales exponentially in
     the number of bits of precision": we fit the doubling factor per bit.
"""
from __future__ import annotations

import math
import time

import numpy as np

from benchmarks.common import QUICK, emit
from repro.api import ExploreConfig, Explorer
from repro.core.funcspec import get_spec


def _timed_gen(ex: Explorer, spec, r: int):
    t0 = time.perf_counter()
    res = ex.explore_r(spec, r)
    return res, time.perf_counter() - t0


def run() -> list[dict]:
    bits = 12 if QUICK else 14
    rows = []
    times = []
    r_range = range(4, min(bits - 2, 9) + 1)
    spec = get_spec("recip", bits)
    # fresh sessions per backend so the envelope cache can't cross-subsidize
    with Explorer(ExploreConfig(engine="pooled", impl="claim21")) as seed_ex, \
            Explorer(ExploreConfig(engine="batched")) as bat_ex:
        for r in r_range:
            res, dt = _timed_gen(seed_ex, spec, r)
            res_b, dt_b = _timed_gen(bat_ex, spec, r)
            times.append((r, dt))
            rows.append({"sweep": "R", "bits": bits, "R": r,
                         "time_s": round(dt, 3),
                         "time_batched_s": round(dt_b, 3),
                         "speedup_vs_seed": round(dt / dt_b, 2),
                         "feasible": res is not None})
            assert (res is None) == (res_b is None)
    rs = np.array([r for r, _ in times], float)
    ts = np.array([t for _, t in times], float)
    slope = float(np.polyfit(np.log(2.0 ** rs), np.log(ts), 1)[0])
    rows.append({"sweep": "R", "bits": bits, "R": "fit",
                 "time_s": f"log2 slope = {slope:.2f} (paper: ~-3)",
                 "time_batched_s": "", "speedup_vs_seed": "",
                 "feasible": ""})

    # precision scaling at R = bits//2 (seed backend, batched alongside)
    times_b = []
    with Explorer(ExploreConfig(engine="pooled", impl="claim21")) as seed_ex, \
            Explorer(ExploreConfig(engine="batched")) as bat_ex:
        for b in range(8, (12 if QUICK else 15) + 1):
            s = get_spec("recip", b)
            _, dt = _timed_gen(seed_ex, s, b // 2)
            _, dt_b = _timed_gen(bat_ex, s, b // 2)
            times_b.append((b, dt))
            rows.append({"sweep": "bits", "bits": b, "R": b // 2,
                         "time_s": round(dt, 3),
                         "time_batched_s": round(dt_b, 3),
                         "speedup_vs_seed": round(dt / dt_b, 2),
                         "feasible": True})
    bs = np.array([b for b, _ in times_b], float)
    ts = np.array([t for _, t in times_b], float)
    growth = float(math.exp(np.polyfit(bs, np.log(ts), 1)[0]))
    rows.append({"sweep": "bits", "bits": "fit", "R": "",
                 "time_s": f"x{growth:.2f} per input bit (exponential)",
                 "time_batched_s": "", "speedup_vs_seed": "",
                 "feasible": ""})
    emit("scaling", rows)
    return rows


if __name__ == "__main__":
    run()
