"""Paper §II-A scaling claims:

  1. runtime vs lookup bits R — "empirical results for a 16 bit design
     suggest the runtime is O(R^-3)": more regions means narrower regions,
     so the quadratic per-region searches shrink faster than region count
     grows. We fit the log-log slope.
  2. runtime vs input bits at fixed relative R — "scales exponentially in
     the number of bits of precision": we fit the doubling factor per bit.
"""
from __future__ import annotations

import math
import time

import numpy as np

from benchmarks.common import QUICK, emit
from repro.core.funcspec import get_spec
from repro.core.generate import generate_for_r


def run() -> list[dict]:
    bits = 12 if QUICK else 14
    rows = []
    times = []
    r_range = range(4, min(bits - 2, 9) + 1)
    for r in r_range:
        t0 = time.perf_counter()
        # paper setup: scalar search with Claim II.1 pruning (§II-A measures
        # the single-threaded PyPy generator; vectorized/hull have different
        # constants and would mask the R-scaling being reproduced)
        res = generate_for_r(get_spec("recip", bits), r, impl="claim21")
        dt = time.perf_counter() - t0
        times.append((r, dt))
        rows.append({"sweep": "R", "bits": bits, "R": r,
                     "time_s": round(dt, 3),
                     "feasible": res is not None})
    rs = np.array([r for r, _ in times], float)
    ts = np.array([t for _, t in times], float)
    slope = float(np.polyfit(np.log(2.0 ** rs), np.log(ts), 1)[0])
    rows.append({"sweep": "R", "bits": bits, "R": "fit",
                 "time_s": f"log2 slope = {slope:.2f} (paper: ~-3)",
                 "feasible": ""})

    # precision scaling at R = bits//2
    times_b = []
    for b in range(8, (12 if QUICK else 15) + 1):
        t0 = time.perf_counter()
        generate_for_r(get_spec("recip", b), b // 2)
        dt = time.perf_counter() - t0
        times_b.append((b, dt))
        rows.append({"sweep": "bits", "bits": b, "R": b // 2,
                     "time_s": round(dt, 3), "feasible": True})
    bs = np.array([b for b, _ in times_b], float)
    ts = np.array([t for _, t in times_b], float)
    growth = float(math.exp(np.polyfit(bs, np.log(ts), 1)[0]))
    rows.append({"sweep": "bits", "bits": "fit", "R": "",
                 "time_s": f"x{growth:.2f} per input bit (exponential)",
                 "feasible": ""})
    emit("scaling", rows)
    return rows


if __name__ == "__main__":
    run()
