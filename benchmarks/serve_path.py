"""Decode-path numerics microbenchmark: fused library vs per-table tables.

Times the jitted single-token decode step (the serving hot loop) on a smoke
config under three numerics variants:

  exact        XLA transcendentals (the no-technique baseline)
  per-table    interp numerics resolving each TableDesign through the
               process session (the pre-library runtime path)
  library      interp numerics bound to one compiled InterpLibrary artifact

and the numerics-only softmax+rmsnorm+activation ensemble on decode-shaped
tensors. Reports steady-state step latency, trace+compile wall-clock, and
speedup columns; rows land in ``artifacts/bench/serve_path_decode.json`` /
``serve_path_ensemble.json`` and are folded into ``BENCH_3.json`` by
``benchmarks.run``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, emit
from repro.api import default_explorer
from repro.configs.base import get_smoke_config
from repro.models import transformer as tf
from repro.numerics.ops import get_numerics
from repro.serve.engine import make_serve_step

ARCHES = ["yi_6b"] if QUICK else ["yi_6b", "mamba2_130m"]
DECODE_ITERS = 20 if QUICK else 50
ENSEMBLE_ITERS = 50 if QUICK else 200


def _steady_interleaved(variants: dict, iters: int) -> dict:
    """Best-of-N per variant, with the variants interleaved round-robin so
    machine-load drift (shared CI runners) hits them all equally instead of
    whichever happened to run last."""
    best = {name: float("inf") for name in variants}
    for name, (fn, args) in variants.items():  # warm-up / compile
        jax.block_until_ready(fn(*args))
    for _ in range(iters):
        for name, (fn, args) in variants.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def _decode_rows() -> list[dict]:
    rows = []
    lib = default_explorer().compile()
    for arch in ARCHES:
        base = get_smoke_config(arch)
        slots, cache_len = 4, 128
        params = tf.init_params(jax.random.key(0), base)
        toks = jnp.zeros((slots, 1), jnp.int32)
        pos = jnp.asarray(8, jnp.int32)
        configs = {
            "exact": (base.replace(numerics="exact"), None),
            "per-table": (base.replace(numerics="interp"), None),
            "library": (base.replace(numerics="interp"), lib),
        }
        variants, compile_s = {}, {}
        for name, (cfg, library) in configs.items():
            caches = tf.init_cache(cfg, slots, cache_len)
            step = jax.jit(make_serve_step(cfg))
            t0 = time.perf_counter()
            jax.block_until_ready(step(params, toks, pos, caches,
                                       library=library))
            compile_s[name] = time.perf_counter() - t0
            variants[name] = (
                lambda c, l, s=step: s(params, toks, pos, c, library=l),
                (caches, library))
        steady = _steady_interleaved(variants, DECODE_ITERS)
        for name in configs:
            rows.append({
                "arch": arch, "numerics": name,
                "decode_ms": steady[name] * 1e3, "compile_s": compile_s[name],
                "speedup_vs_pertable": steady["per-table"] / steady[name],
                "compile_speedup_vs_pertable":
                    compile_s["per-table"] / compile_s[name],
            })
    return rows


def _ensemble_rows() -> list[dict]:
    """softmax + rmsnorm + activations on decode-shaped tensors, numerics
    only — isolates table-lookup cost from the model's matmuls."""
    lib = default_explorer().compile()
    rng = np.random.default_rng(0)
    b, h, s, d = (4, 8, 256, 512) if QUICK else (8, 16, 1024, 1024)
    scores = jnp.asarray(rng.normal(0, 2, (b, h, 1, s)).astype(np.float32))
    hid = jnp.asarray(rng.normal(0, 1, (b, 1, d)).astype(np.float32))
    gamma = jnp.asarray(rng.normal(1, 0.1, d).astype(np.float32))

    def ensemble(num, sc, x, g):
        p = num.softmax(sc, axis=-1)
        y = num.rmsnorm(x, g)
        return p, num.silu(y), num.gelu(y), num.softplus(y)

    rows, variants, compile_s = [], {}, {}
    for name, num in [("exact", get_numerics("exact")),
                      ("per-table", get_numerics("interp")),
                      ("library", get_numerics("interp", lib))]:
        fn = jax.jit(lambda sc, x, g, n=num: ensemble(n, sc, x, g))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(scores, hid, gamma))
        compile_s[name] = time.perf_counter() - t0
        variants[name] = (fn, (scores, hid, gamma))
    steady = _steady_interleaved(variants, ENSEMBLE_ITERS)
    for name in variants:
        rows.append({
            "numerics": name, "ensemble_us": steady[name] * 1e6,
            "compile_s": compile_s[name],
            "speedup_vs_pertable": steady["per-table"] / steady[name],
            "compile_speedup_vs_pertable":
                compile_s["per-table"] / compile_s[name],
        })
    return rows


def run() -> None:
    emit("serve_path_decode", _decode_rows(),
         ["arch", "numerics", "decode_ms", "compile_s",
          "speedup_vs_pertable", "compile_speedup_vs_pertable"])
    emit("serve_path_ensemble", _ensemble_rows(),
         ["numerics", "ensemble_us", "compile_s", "speedup_vs_pertable",
          "compile_speedup_vs_pertable"])


if __name__ == "__main__":
    run()
