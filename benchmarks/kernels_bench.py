"""Kernel micro-benchmarks (TPU adaptation layer).

Times the pure-jnp reference vs the Pallas kernel in interpret mode for each
kernel (interpret mode is a *correctness* vehicle on CPU — wall-clock there
is not TPU performance; the structural numbers that matter for TPU are in
EXPERIMENTS.md §Roofline). Also reports the certified ULP bound of each
numerics table and the measured max error of the approx ops vs float64.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, emit, timed
from repro.numerics import ops as nops
from repro.api import get_table


def run() -> list[dict]:
    rows = []
    n = 1 << (14 if QUICK else 18)
    key = jax.random.key(0)

    # table-backed transcendental accuracy vs float64
    x_neg = -jax.random.uniform(key, (n,), jnp.float32, 0, 30)
    got = np.asarray(nops.approx_exp_neg(x_neg), np.float64)
    want = np.exp(np.asarray(x_neg, np.float64))
    rel = np.max(np.abs(got - want) / np.maximum(want, 1e-300))
    rows.append({"op": "exp_neg", "n": n, "max_rel_err": float(rel),
                 "table": "exp2neg 12b R6"})

    x_pos = jax.random.uniform(key, (n,), jnp.float32, 1e-3, 1e3)
    got = np.asarray(nops.approx_recip_pos(x_pos), np.float64)
    want = 1.0 / np.asarray(x_pos, np.float64)
    rows.append({"op": "recip_pos", "n": n,
                 "max_rel_err": float(np.max(np.abs(got - want) / want)),
                 "table": "recip 12b R6"})

    got = np.asarray(nops.approx_rsqrt_pos(x_pos), np.float64)
    want = 1.0 / np.sqrt(np.asarray(x_pos, np.float64))
    rows.append({"op": "rsqrt_pos", "n": n,
                 "max_rel_err": float(np.max(np.abs(got - want) / want)),
                 "table": "rsqrt 12b R6"})

    x = jax.random.normal(key, (128, 512 if QUICK else 2048))
    got = np.asarray(nops.approx_softmax(x), np.float64)
    want = jax.nn.softmax(np.asarray(x, np.float64), axis=-1)
    rows.append({"op": "softmax", "n": x.size,
                 "max_rel_err": float(np.max(np.abs(got - want) / np.maximum(want, 1e-12))),
                 "table": f"bound {nops.softmax_ulp_bound():.2e}"})
    emit("numerics_accuracy", rows)

    # kernel interpret-mode vs jnp-ref timing (informational on CPU)
    krows = []
    design = get_table("recip")
    from repro.kernels.interp.ops import table_eval
    codes = jax.random.randint(key, (1 << 14,), 0, 1 << design.in_bits, jnp.int32)
    ref = jax.jit(lambda c: table_eval(c, design, use_kernel=False))
    ker = jax.jit(lambda c: table_eval(c, design, use_kernel=True, interpret=True))
    o1, t_ref = timed(lambda: jax.block_until_ready(ref(codes)), repeat=3)
    o2, t_ker = timed(lambda: jax.block_until_ready(ker(codes)), repeat=1)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    krows.append({"kernel": "interp", "n": codes.size,
                  "jnp_ms": round(t_ref * 1e3, 3),
                  "pallas_interpret_ms": round(t_ker * 1e3, 2),
                  "bit_exact": True})

    from repro.kernels.softmax.ops import approx_softmax_fused
    xs = jax.random.normal(key, (256, 1024))
    r = jax.jit(lambda a: approx_softmax_fused(a, use_kernel=False))
    kfn = jax.jit(lambda a: approx_softmax_fused(a, interpret=True))
    o1, t_ref = timed(lambda: jax.block_until_ready(r(xs)), repeat=3)
    o2, t_ker = timed(lambda: jax.block_until_ready(kfn(xs)), repeat=1)
    err = float(jnp.max(jnp.abs(o1 - o2)))
    krows.append({"kernel": "softmax", "n": xs.size,
                  "jnp_ms": round(t_ref * 1e3, 3),
                  "pallas_interpret_ms": round(t_ker * 1e3, 2),
                  "max_abs_diff": err})

    from repro.kernels.flashattn.ops import attention_fused
    qf = jax.random.normal(key, (1, 256, 2, 128))
    kf = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 2, 128))
    vf = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 2, 128))
    r = jax.jit(lambda a, b, c: attention_fused(a, b, c, use_kernel=False))
    kfn = jax.jit(lambda a, b, c: attention_fused(a, b, c, interpret=True))
    o1, t_ref = timed(lambda: jax.block_until_ready(r(qf, kf, vf)), repeat=3)
    o2, t_ker = timed(lambda: jax.block_until_ready(kfn(qf, kf, vf)), repeat=1)
    err = float(jnp.max(jnp.abs(o1 - o2)))
    krows.append({"kernel": "flashattn", "n": qf.size,
                  "jnp_ms": round(t_ref * 1e3, 3),
                  "pallas_interpret_ms": round(t_ker * 1e3, 2),
                  "max_abs_diff": err})

    from repro.kernels.dspace.ops import envelopes_pallas, envelopes_ref_jnp
    from repro.core.designspace import envelopes as env_np
    spec_lo, spec_hi = get_table("recip"), None  # reuse bound arrays below
    from repro.core.funcspec import get_spec
    lo, hi = get_spec("recip", 12).region_bounds(4)
    L, U = lo[0], hi[0]
    (mp, sp), t_ker = timed(lambda: envelopes_pallas(L, U), repeat=1)
    (mr, sr), t_ref = timed(lambda: env_np(L, U), repeat=3)
    np.testing.assert_allclose(mp[1:], mr[1:], rtol=1e-6)  # kernel is f32
    krows.append({"kernel": "dspace_envelopes", "n": len(L),
                  "jnp_ms": round(t_ref * 1e3, 3),
                  "pallas_interpret_ms": round(t_ker * 1e3, 2),
                  "max_abs_diff": 0.0})
    emit("kernels", krows)
    return rows + krows


if __name__ == "__main__":
    run()
