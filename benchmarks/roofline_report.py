"""Roofline table from the dry-run artifacts (deliverable g aggregation).

Reads artifacts/dryrun/*.json (produced by ``python -m repro.launch.dryrun
--all``) and prints the three-term table; no compilation happens here so the
bench suite stays fast. Cells missing from the artifact directory are
reported as such — run the sweep first.
"""
from __future__ import annotations

import pathlib

from benchmarks.common import emit
from repro.launch.roofline import DEFAULT_DIR, cell_roofline, load_records


def run() -> list[dict]:
    rows = []
    recs = (load_records(pathlib.Path(DEFAULT_DIR), tag="")
            + load_records(pathlib.Path(DEFAULT_DIR), tag="_opt"))
    for rec in recs:
        r = cell_roofline(rec)
        if r is None:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"],
                         "variant": "optimized" if rec.get("tag") else "baseline",
                         "dominant": rec["status"],
                         "roofline_frac": "", "useful_ratio": "",
                         "compute_ms": "", "memory_ms": "", "collective_ms": ""})
        else:
            rows.append({
                "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "variant": "optimized" if r.get("tag") else "baseline",
                "compute_ms": round(r["compute_s"] * 1e3, 2),
                "memory_ms": round(r["memory_s"] * 1e3, 2),
                "collective_ms": round(r["collective_s"] * 1e3, 2),
                "dominant": r["dominant"],
                "useful_ratio": round(r["useful_ratio"], 3),
                "roofline_frac": round(r["roofline_frac"], 4),
            })
    if not rows:
        rows.append({"arch": "(run `python -m repro.launch.dryrun --all` first)",
                     "shape": "", "mesh": "", "dominant": "",
                     "roofline_frac": "", "useful_ratio": "",
                     "compute_ms": "", "memory_ms": "", "collective_ms": ""})
    emit("roofline", rows)
    return rows


if __name__ == "__main__":
    run()
