"""Shared benchmark utilities: timing, CSV/markdown emission, quick mode."""
from __future__ import annotations

import os
import pathlib
import time

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "bench"

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"


def timed(fn, *args, repeat: int = 1, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def emit(name: str, rows: list[dict], cols: list[str] | None = None):
    """Print a markdown table and persist rows as a schema-versioned
    snapshot (``{name: rows}`` inside the ``repro.dse.record`` envelope —
    a pre-existing bare-list file is backed up to ``*.pre-schema.json``
    once and migrated, never silently overwritten)."""
    if not rows:
        print(f"## {name}\n(no rows)")
        return
    cols = cols or list(rows[0].keys())
    print(f"\n## {name}\n")
    print("| " + " | ".join(cols) + " |")
    print("|" + "---|" * len(cols))
    for r in rows:
        print("| " + " | ".join(_fmt(r.get(c, "")) for c in cols) + " |")
    from repro.dse.record import update_snapshot

    update_snapshot(ART / f"{name}.json", {name: rows},
                    seed=0, meta_extra={"quick": QUICK})


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e4 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)
