"""Fused decode datapath benchmark: tokens/sec + dispatch counts, fused
tick vs the ISSUE-3/4 serial serve path.

Drives a realistic continuous-batching workload (mixed prompt lengths, more
requests than slots, so admissions land mid-flight) through two engines
over the same prompts and params:

  serial   fused=False — the PR 3/4 path: per decoded token, a token/pos
           upload, one decode dispatch, and a host argmax round-trip
  fused    fused=True  — ONE donated-buffer dispatch per chunk of up to
           ``horizon`` decode steps; greedy argmax inside the program;
           interp numerics lower through the library-bound fused kernels

for both exact and library-bound interp numerics. Reports steady-state
tokens/sec, host program dispatches and device<->host transfers per decoded
token (from ``ServeEngine.stats``), and the fused-vs-serial speedup. The
exact-numerics pair also asserts bitwise token equality (same decode
program, only the dispatch granularity changes). Rows land in
``artifacts/bench/decode_fused.json`` and are folded into ``BENCH_5.json``
by ``benchmarks.run`` (CI bench-smoke uploads it).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import QUICK, emit
from repro.api import default_explorer
from repro.configs.base import get_smoke_config
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine

ARCHES = ["yi_6b"] if QUICK else ["yi_6b", "mamba2_130m"]
N_REQ = 8 if QUICK else 12
MAX_NEW = 24 if QUICK else 48
SLOTS, CACHE_LEN, HORIZON = 4, 128, 8
REPEATS = 2 if QUICK else 3


def _prompts(cfg, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, 4 + (i * 5) % 23).astype(np.int32)
            for i in range(N_REQ)]


def _run_once(cfg, params, lib, prompts, fused: bool):
    eng = ServeEngine(cfg, params, slots=SLOTS, cache_len=CACHE_LEN,
                      library=lib, fused=fused, horizon=HORIZON)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new=MAX_NEW))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    streams = [r.out for r in sorted(done, key=lambda r: r.rid)]
    return toks / dt, eng.stats, streams


def _rows() -> list[dict]:
    rows = []
    for arch in ARCHES:
        base = get_smoke_config(arch)
        params = tf.init_params(jax.random.key(0), base)
        prompts = _prompts(base)
        for numerics in ("exact", "interp"):
            cfg = base.replace(numerics=numerics)
            lib = default_explorer().compile() if numerics == "interp" else None
            best = {False: (0.0, None), True: (0.0, None)}
            streams = {}
            # interleaved best-of-N (cf. serve_path): machine-load drift on
            # shared runners hits both engines equally, not whichever ran
            # last; the extra first round warms the jit cache
            for rep in range(REPEATS + 1):
                for fused in (False, True):
                    t, stats, out = _run_once(cfg, params, lib, prompts, fused)
                    if rep and t > best[fused][0]:
                        best[fused] = (t, stats)
                    streams[fused] = out
            if numerics == "exact":
                # same decode program either way -> greedy streams identical
                assert streams[True] == streams[False], \
                    f"{arch}: fused tokens diverged from the serial oracle"
            for fused in (False, True):
                tps, stats = best[fused]
                steps = max(stats["decode_steps"], 1)
                rows.append({
                    "arch": arch, "numerics": numerics,
                    "engine": "fused" if fused else "serial",
                    "tokens_per_s": tps,
                    "dispatches_per_token": stats["dispatches"] / steps,
                    "transfers_per_token": stats["transfers"] / steps,
                    "speedup_vs_serial": tps / best[False][0],
                })
    return rows


def run() -> None:
    emit("decode_fused", _rows(),
         ["arch", "numerics", "engine", "tokens_per_s",
          "dispatches_per_token", "transfers_per_token", "speedup_vs_serial"])


if __name__ == "__main__":
    run()
