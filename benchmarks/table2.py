"""Paper Table II: LUT column widths [a, b, c] of the complete-design-space
decision procedure vs the Remez (FloPoCo/Sollya stand-in) baseline at equal
LUT height. The paper's observation to reproduce: Remez needs a *wider* `a`
column (bigger a*x^2 multiplier array), while the proposed tables may spend
more bits on `c` (cheap ROM) — total multiplier area favours the proposal.
"""
from __future__ import annotations

from benchmarks.common import QUICK, emit
from repro.api import Explorer, get_spec
from repro.core.remez import generate_remez_table

# (kind, bits, kwargs, R, degree) — paper rows are (recip,23,R7), (log2,16,R8),
# (exp,10,R6); 23-bit is out of budget so recip drops to 14 bits (documented).
CASES_FULL = [
    ("recip", 14, {}, 6, 2),
    ("log2", 16, {"out_bits": 17}, 8, 2),
    ("exp2", 10, {"out_bits": 10}, 6, 2),
]
CASES_QUICK = [
    ("recip", 10, {}, 5, 2),
    ("exp2", 10, {"out_bits": 10}, 5, 2),
]


def run() -> list[dict]:
    rows = []
    ex = Explorer()
    for kind, bits, kw, r, degree in (CASES_QUICK if QUICK else CASES_FULL):
        spec = get_spec(kind, bits, **kw)
        res = ex.explore_r(spec, r, degree=degree)
        if res is None:
            rows.append({"function": kind, "bits": bits, "R": r,
                         "status": "infeasible"})
            continue
        wa, wb, wc = res.design.lut_widths
        try:
            rz = generate_remez_table(spec, r, degree=degree)
            assert rz is not None
            ra, rb, rc = rz.widths
            rz_s = f"[{ra},{rb},{rc}] = {ra+rb+rc}"
            a_nar = wa <= ra
        except Exception as e:
            rz_s, a_nar = f"failed: {e}", None
        rows.append({
            "function": kind, "bits": bits, "R": r,
            "proposed_LUT": f"[{wa},{wb},{wc}] = {wa+wb+wc}",
            "remez_LUT": rz_s,
            "proposed_a_narrower": a_nar,
        })
    emit("table2", rows)
    return rows


if __name__ == "__main__":
    run()
