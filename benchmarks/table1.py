"""Paper Table I (proposed columns): generate reciprocal / log2 / exp2 at the
paper's precisions, sweep LUT heights, pick best area-delay, report runtime,
chosen LUB, lin/quad selection and the area/delay proxy.

The paper's 23-bit rows took 39-78 *hours* on a Xeon; those are expressible
here but out of container budget (BENCH_QUICK trims to 10/12-bit; full mode
runs 10 and 16 bit as published). DesignWare columns are proprietary synthesis
results we cannot run; we reproduce the *proposed* side and compare against
our Remez baseline via the same area proxy (DESIGN.md §7.1).
"""
from __future__ import annotations

import time

from benchmarks.common import QUICK, emit
from repro.api import Explorer, get_spec
from repro.core.remez import generate_remez_table
from repro.core import area as area_model

CASES_FULL = [
    ("recip", 10, {}), ("recip", 16, {}),
    ("log2", 10, {"out_bits": 11}), ("log2", 16, {"out_bits": 17}),
    ("exp2", 10, {"out_bits": 10}), ("exp2", 16, {"out_bits": 16}),
]
CASES_QUICK = [
    ("recip", 10, {}), ("log2", 10, {"out_bits": 11}), ("exp2", 10, {"out_bits": 10}),
    ("recip", 12, {}),
]


def run() -> list[dict]:
    rows = []
    ex = Explorer()
    for kind, bits, kw in (CASES_QUICK if QUICK else CASES_FULL):
        spec = get_spec(kind, bits, **kw)
        t0 = time.perf_counter()
        res = ex.explore(spec)
        runtime = time.perf_counter() - t0
        if not res:
            rows.append({"function": kind, "bits": bits, "status": "infeasible"})
            continue
        best = res.best
        d = best.design
        # Remez comparison point at the same LUT height (our DesignWare stand-in)
        try:
            rz = generate_remez_table(spec, d.lookup_bits, degree=d.degree)
            assert rz is not None
            rz_ad = area_model.estimate(rz.design)
            rz_area, rz_delay = rz_ad.area, rz_ad.delay
        except Exception as e:
            rz_area = rz_delay = float("nan")
        rows.append({
            "function": kind, "bits": f"{bits}->{d.out_bits}",
            "runtime_s": round(runtime, 2),
            "LUB": f"{d.lookup_bits} ({'lin' if d.degree == 1 else 'quad'})",
            "delay": round(best.delay, 2), "area": round(best.area, 0),
            "area_x_delay": round(best.area_delay, 0),
            "remez_area": round(rz_area, 0), "remez_delay": round(rz_delay, 2),
            "remez_axd": round(rz_area * rz_delay, 0),
            "min_feasible_R": res.min_regions_r,
        })
    emit("table1", rows)
    return rows


if __name__ == "__main__":
    run()
