"""Segmented-ROM benchmark (ISSUE 8): non-uniform vs uniform layouts.

Two tables, folded into ``BENCH_8.json`` by ``benchmarks.run`` (the CI
segment-smoke job uploads it):

  segment_rom     per kind at the registry default width: the uniform
                  minimal-R design vs the greedy dyadic segmentation
                  (:func:`repro.segment.explore_segmented`, depth capped at
                  the uniform R). Both verify against the same §II envelope
                  — identical faithful-rounding guarantee — so the row
                  delta is pure ROM savings; the segmented row count
                  *includes* the packed segment-index table. Also reports
                  the asic-target area x delay of each layout (decoder
                  modeled for the segmented one).
  segment_serve   modeled decode throughput of a fused continuous-batching
                  serve over (a) the all-uniform compiled library and (b)
                  ``compile_segmented`` with every improvable slot swapped
                  to ROM v2. The dispatch/transfer counters are
                  deterministic and MUST match: the segment-index gather
                  happens inside the already-dispatched fused kernels
                  (zero extra dispatches) — the run() assertion enforces
                  it.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import QUICK, emit
from repro.api import default_explorer
from repro.api.config import spec_for
from repro.core.area import AreaDelay
from repro.segment import (estimate_segmented, explore_segmented,
                           min_uniform_depth)

KINDS = ("exp2neg", "recip", "sigmoid") if QUICK else (
    "exp2neg", "recip", "sigmoid", "tanh", "gelu", "silu")

SLOTS, CACHE_LEN, HORIZON = 2, 64, 8
N_REQ, MAX_NEW = 3, 8
SEED = 0

# modeled per-dispatch/transfer costs — same constants as repro.dse.probe
DISPATCH_COST_S = 1e-4
TRANSFER_COST_S = 2e-5


def _rom_rows(ex) -> list[dict]:
    from repro.api.target import get_target

    asic = get_target("asic")
    rows = []
    for kind in KINDS:
        spec = spec_for(kind, None)
        r = min_uniform_depth(spec, engine="batched")
        uni = ex.explore_r(spec, r, target="asic")
        assert uni is not None, f"uniform {kind} infeasible at minimal R {r}"
        sd = explore_segmented(spec, max_depth=r, engine="batched")
        u_rows = 1 << r
        u_ad = AreaDelay(uni.area, uni.delay)
        row = {
            "kind": kind, "bits": spec.in_bits, "uniform_R": r,
            "uniform_rows": u_rows,
            "uniform_area_delay": round(u_ad.product, 1),
        }
        if sd is None:
            row.update({"seg_leaves": None, "seg_rows": None,
                        "rows_saved": 0, "seg_area_delay": None,
                        "verified": uni.design.verify(spec)[0]})
        else:
            s_ad = estimate_segmented(sd, asic)
            ok_u = uni.design.verify(spec)[0]
            ok_s = sd.verify(spec)[0]
            row.update({
                "seg_leaves": sd.n_leaves, "seg_rows": sd.rows_used,
                "rows_saved": u_rows - sd.rows_used,
                "seg_area_delay": round(s_ad.product, 1),
                "verified": bool(ok_u and ok_s),
            })
        rows.append(row)
    return rows


def _serve_once(cfg, params, lib) -> dict:
    from repro.serve.engine import Request, ServeEngine

    eng = ServeEngine(cfg, params, slots=SLOTS, cache_len=CACHE_LEN,
                      library=lib, fused=True, horizon=HORIZON)
    rng = np.random.default_rng(SEED)
    for i in range(N_REQ):
        prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        eng.submit(Request(i, prompt, max_new=MAX_NEW))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    steps = max(eng.stats["decode_steps"], 1)
    modeled_t = (eng.stats["dispatches"] * DISPATCH_COST_S
                 + eng.stats["transfers"] * TRANSFER_COST_S)
    return {
        "tokens": sum(len(r.out) for r in done),
        "wall_s": round(wall, 4),
        "modeled_tokens_per_s": round(steps / max(modeled_t, 1e-12), 1),
        "dispatches_per_token": round(eng.stats["dispatches"] / steps, 4),
        "transfers_per_token": round(eng.stats["transfers"] / steps, 4),
    }


def _serve_rows(ex) -> list[dict]:
    from repro.configs.base import get_smoke_config
    from repro.models import transformer as tf

    cfg = get_smoke_config("yi_6b").replace(numerics="interp")
    params = tf.init_params(jax.random.key(SEED), cfg)
    lib_u = ex.compile()
    lib_s = ex.compile_segmented()
    rows = []
    for name, lib in (("uniform", lib_u), ("segmented", lib_s)):
        r = _serve_once(cfg, params, lib)
        r["library"] = name
        r["rom_version"] = lib.manifest()["version"]
        r["segmented_kinds"] = ",".join(lib.segmented_kinds) or "-"
        r["rom_rows_total"] = sum(m.rows_used for m in lib.metas)
        rows.append(r)
    return rows


def run():
    ex = default_explorer()
    rom = _rom_rows(ex)
    serve = _serve_rows(ex)
    emit("segment_rom", rom)
    emit("segment_serve", serve,
         cols=["library", "rom_version", "segmented_kinds", "rom_rows_total",
               "tokens", "modeled_tokens_per_s", "dispatches_per_token",
               "transfers_per_token", "wall_s"])

    improved = [r for r in rom if r.get("rows_saved", 0) > 0 and r["verified"]]
    assert improved, ("no kind saved ROM rows at matched accuracy — "
                      "the segmentation subsystem is not paying for itself")
    u, s = serve[0], serve[1]
    for c in ("dispatches_per_token", "transfers_per_token"):
        assert u[c] == s[c], \
            f"segmented library changed the {c} counter: {u[c]} -> {s[c]}"
    assert s["rom_rows_total"] < u["rom_rows_total"], \
        "segmented library stores no fewer ROM rows than uniform"


if __name__ == "__main__":
    run()
