"""Chaos serving benchmark (ISSUE 7): the robustness layer under load.

Drives the fault-injection harness (:mod:`repro.faults`) through real
continuous-batching runs and reports what the serving-robustness layer
costs and what it buys (DESIGN.md §14):

  chaos_overhead   healthy-path cost of the guard rails — baseline fused
                   serve vs the same run with an fsync'd journal, a
                   per-request deadline, and periodic resident-ROM
                   verification. Counter columns (dispatches/transfers per
                   token) are deterministic and must NOT move: the
                   watchdog sentinel rides the existing token download.
  chaos_faults     each injected fault family (NaN'd tick, dropped tick,
                   corrupt ROM, deadline storm) against the engine:
                   structured failures, watchdog trips, degradations, and
                   the rung the engine lands on — plus how many requests
                   still complete after degradation.
  chaos_recovery   kill-9 at the tick crash point, ``ServeEngine.resume``:
                   replayed teacher-forcing steps vs decode steps saved
                   (completed work skipped), recovery wall time, and a
                   bitwise check of the recovered streams against an
                   uninterrupted run.

Rows land in ``artifacts/bench/chaos_*.json`` and are folded into
``BENCH_7.json`` by ``benchmarks.run`` (CI chaos-smoke uploads it).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import QUICK, emit
from repro.api import default_explorer
from repro.configs.base import get_smoke_config
from repro.faults import (FaultClock, TickFaultInjector, arm_crashpoint,
                          flip_rom_bit, reset_crashpoints)
from repro.faults.inject import Crashed
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine
from repro.serve.journal import load_requests

N_REQ = 6 if QUICK else 10
MAX_NEW = 16 if QUICK else 32
SLOTS, CACHE_LEN, HORIZON = 4, 128, 8
SEED = 0


def _prompts(cfg):
    rng = np.random.default_rng(SEED)
    return [rng.integers(0, cfg.vocab_size, 4 + (i * 5) % 19).astype(np.int32)
            for i in range(N_REQ)]


def _engine(cfg, params, **kw):
    kw.setdefault("slots", SLOTS)
    kw.setdefault("cache_len", CACHE_LEN)
    kw.setdefault("horizon", HORIZON)
    return ServeEngine(cfg, params, **kw)


def _serve(eng, prompts, max_new=MAX_NEW):
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new=max_new))
    t0 = time.perf_counter()
    done = eng.run()
    return done, time.perf_counter() - t0


def _overhead_rows(cfg, params, lib, prompts, tmp):
    # warm the jit cache first so the baseline row isn't charged compile time
    _serve(_engine(cfg, params, library=lib, fused=True), prompts)
    rows = []
    scenarios = [
        ("baseline", {}),
        ("journal", {"journal": str(tmp / "bench_serve.jsonl")}),
        ("deadline+rom_verify", {"deadline_s": 3600.0, "verify_rom_every": 4}),
    ]
    for name, kw in scenarios:
        eng = _engine(cfg, params, library=lib, fused=True, **kw)
        done, dt = _serve(eng, prompts)
        toks = sum(len(r.out) for r in done)
        steps = max(eng.stats["decode_steps"], 1)
        rows.append({
            "scenario": name, "tokens": toks, "wall_s": round(dt, 4),
            "tokens_per_s": round(toks / dt, 1),
            "dispatches_per_token": round(eng.stats["dispatches"] / steps, 4),
            "transfers_per_token": round(eng.stats["transfers"] / steps, 4),
            "rom_verifies": eng.stats["rom_verifies"],
        })
    return rows


def _fault_rows(cfg, params, icfg, lib, prompts):
    rows = []

    def row(name, eng, done, note=""):
        rows.append({
            "fault": name, "finished": len(eng.finished),
            "failed": len(eng.failed),
            "watchdog_trips": eng.stats["watchdog_trips"],
            "degradations": eng.stats["degradations"],
            "rom_faults": eng.stats["rom_faults"],
            "final_rung": eng._rung(), "note": note,
        })

    # NaN'd ticks until the engine walks off the fused rung
    eng = _engine(cfg, params, fused=True, watchdog_limit=2)
    TickFaultInjector("nan", every_n=1, limit=2).install(eng)
    done, _ = _serve(eng, prompts)
    row("nan_tick_x2", eng, done, "poisoned chunks never streamed")

    # one dropped tick: structured failure, no silent progress
    eng = _engine(cfg, params, fused=True, watchdog_limit=100)
    TickFaultInjector("drop", every_n=1, limit=1).install(eng)
    done, _ = _serve(eng, prompts)
    row("dropped_tick", eng, done)

    # corrupt resident ROM: detected at construction, straight to exact
    eng = _engine(icfg, params, fused=True, library=flip_rom_bit(lib, seed=3))
    done, _ = _serve(eng, prompts)
    row("rom_bit_flip", eng, done, "verify_resident at construction")

    # deadline storm: a clock jump expires everything still queued
    clk = FaultClock()
    eng = _engine(cfg, params, fused=True, clock=clk, deadline_s=1.0,
                  slots=1)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new=MAX_NEW))
    eng.step(HORIZON)
    clk.advance(2.0)
    eng.run()
    row("deadline_storm", eng, None,
        f"expired={eng.stats['expired']}")
    return rows


def _recovery_rows(cfg, params, prompts, tmp):
    # uninterrupted reference
    ref_eng = _engine(cfg, params, fused=True)
    ref_done, _ = _serve(ref_eng, prompts)
    want = {r.rid: r.out for r in ref_done}

    jp = tmp / "bench_crash.jsonl"
    eng = _engine(cfg, params, fused=True, horizon=2, journal=str(jp))
    arm_crashpoint("serve.tick.emitted", after=3)
    crashed = False
    try:
        _serve(eng, prompts)
    except Crashed:
        crashed = True
    reset_crashpoints()
    pre = load_requests(jp)
    durable_tokens = sum(len(st.out) for st in pre.values())

    t0 = time.perf_counter()
    res = ServeEngine.resume(str(jp), cfg, params, slots=SLOTS,
                             cache_len=CACHE_LEN, horizon=2)
    res.run()
    dt = time.perf_counter() - t0
    final = load_requests(jp)
    bitwise = all(st.out == want[rid] for rid, st in final.items())
    return [{
        "crashed": crashed, "durable_tokens_at_crash": durable_tokens,
        "skipped_done": res.stats["resume_skipped_done"],
        "replay_steps": res.stats["resume_replay_steps"],
        "fresh_decode_steps": res.stats["decode_steps"],
        "recovery_wall_s": round(dt, 4),
        "streams_bitwise_equal": bitwise,
    }]


def run():
    import tempfile

    cfg = get_smoke_config("yi_6b")
    icfg = cfg.replace(numerics="interp")
    params = tf.init_params(jax.random.key(SEED), cfg)
    lib = default_explorer().compile()
    prompts = _prompts(cfg)

    with tempfile.TemporaryDirectory() as td:
        import pathlib

        tmp = pathlib.Path(td)
        overhead = _overhead_rows(icfg, params, lib, prompts, tmp)
        faults = _fault_rows(cfg, params, icfg, lib, prompts)
        recovery = _recovery_rows(cfg, params, prompts, tmp)

    emit("chaos_overhead", overhead)
    emit("chaos_faults", faults)
    emit("chaos_recovery", recovery)

    assert recovery[0]["streams_bitwise_equal"], \
        "resumed streams diverged from the uninterrupted run"
    base = overhead[0]
    for r in overhead[1:]:
        assert r["dispatches_per_token"] == base["dispatches_per_token"], \
            f"{r['scenario']}: robustness knobs changed the dispatch counters"


if __name__ == "__main__":
    run()
