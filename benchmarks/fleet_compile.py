"""Fleet engine vs serial per-kind manifest compile (ISSUE 4 tentpole).

Two measurements:

* ``fleet_compile`` — ``Explorer.compile()`` over the full
  :data:`DEFAULT_LIBRARY_KINDS` manifest at the registry's 12-bit specs,
  cold table cache every run: the serial per-kind path (``fleet=False``,
  one ``get_table`` ladder per kind) vs the fleet engine (every probe's
  §II front half as one stacked array program + the decision procedures in
  lockstep). Both produce bit-identical libraries (asserted).
* ``fleet_min_regions`` — the manifest min-R query: per-spec
  ``min_regions`` vs the lockstep ``min_regions_many`` that answers each
  round's (spec, R) frontier with one stacked feasibility program.

These rows feed artifacts/bench/BENCH_4.json (see benchmarks/run.py).
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import QUICK, emit
from repro.api import ExploreConfig, Explorer
from repro.api.config import DEFAULTS, spec_for
from repro.api.library import DEFAULT_LIBRARY_KINDS


def _compile_time(fleet: bool, repeat: int) -> tuple[float, object]:
    best = float("inf")
    lib = None
    for _ in range(repeat):
        with Explorer(ExploreConfig(cache_dir=tempfile.mkdtemp(),
                                    fleet=fleet)) as ex:
            t0 = time.perf_counter()
            lib = ex.compile()
            best = min(best, time.perf_counter() - t0)
    return best, lib


def run() -> list[dict]:
    repeat = 2 if QUICK else 4
    t_fleet, lib_fleet = _compile_time(True, repeat)
    t_serial, lib_serial = _compile_time(False, repeat)
    # the golden contract the speedup is NOT allowed to buy anything with
    assert lib_fleet.metas == lib_serial.metas
    np.testing.assert_array_equal(np.asarray(lib_fleet.coeffs),
                                  np.asarray(lib_serial.coeffs))
    rows = [
        {"path": "serial per-kind (fleet off)", "kinds": len(DEFAULT_LIBRARY_KINDS),
         "bits": 12, "time_s": round(t_serial, 3), "speedup": 1.0},
        {"path": "fleet (stacked probes + lockstep decisions)",
         "kinds": len(DEFAULT_LIBRARY_KINDS), "bits": 12,
         "time_s": round(t_fleet, 3),
         "speedup": round(t_serial / t_fleet, 2) if t_fleet else float("inf"),
         "bit_identical": True},
    ]
    emit("fleet_compile", rows)

    bits = 10 if QUICK else 12
    specs = [spec_for(k, bits) for k in DEFAULTS]
    t_many = t_one = float("inf")
    for _ in range(repeat):
        with Explorer() as ex:
            t0 = time.perf_counter()
            many = ex.min_regions_many(specs)
            t_many = min(t_many, time.perf_counter() - t0)
        with Explorer(ExploreConfig(fleet=False)) as ex:
            t0 = time.perf_counter()
            serial = [ex.min_regions(s) for s in specs]
            t_one = min(t_one, time.perf_counter() - t0)
    assert many == serial, (many, serial)
    rows2 = [
        {"path": "serial per-spec min_regions", "specs": len(specs),
         "bits": bits, "time_s": round(t_one, 3), "speedup": 1.0},
        {"path": "fleet min_regions_many (lockstep)", "specs": len(specs),
         "bits": bits, "time_s": round(t_many, 3),
         "speedup": round(t_one / t_many, 2) if t_many else float("inf")},
    ]
    emit("fleet_min_regions", rows2)
    return rows + rows2


if __name__ == "__main__":
    run()
