"""Per-layer heterogeneous numerics benchmark (ISSUE 9): the NumericsPlan
serving stack plus the budget-driven auto-assigner.

Two tables, folded into ``BENCH_9.json`` by ``benchmarks.run`` (the CI
plan-smoke job uploads it):

  plan_bitwise    the degenerate-plan acceptance oracle: a fused serve
                  under ``NumericsPlan.uniform("interp-fused", L)`` vs the
                  homogeneous ``numerics="interp"`` fused engine — token
                  streams must be *bitwise identical* (the run() assertion
                  enforces it; a drift here means the plan machinery is
                  not pure plumbing in the uniform case).
  plan_auto       per arch: :func:`repro.plan.assign.auto_plan` under the
                  whole-model output-error budget, verified end to end
                  (measured prefill-logit error vs all-exact MUST fit the
                  budget — asserted), plus a real fused serve under the
                  assigned mixed plan with the engine's deterministic
                  dispatch/transfer counters. The assigned plan MUST beat
                  the all-exact plan on modeled decode tokens/sec —
                  that gap is the subsystem's reason to exist.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_smoke_config
from repro.models import transformer as tf
from repro.plan import NumericsPlan
from repro.plan.assign import auto_plan

ARCHS = ("yi_6b", "minicpm3_4b")
BUDGET = 0.05
SLOTS, CACHE_LEN, HORIZON = 2, 64, 8
N_REQ, MAX_NEW = 3, 8
SEED = 0

# modeled per-dispatch/transfer costs — same constants as repro.dse.probe
DISPATCH_COST_S = 1e-4
TRANSFER_COST_S = 2e-5


def _serve_once(cfg, params) -> tuple[dict, dict]:
    from repro.serve.engine import Request, ServeEngine

    eng = ServeEngine(cfg, params, slots=SLOTS, cache_len=CACHE_LEN,
                      fused=True, horizon=HORIZON)
    rng = np.random.default_rng(SEED)
    for i in range(N_REQ):
        prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        eng.submit(Request(i, prompt, max_new=MAX_NEW))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    steps = max(eng.stats["decode_steps"], 1)
    modeled_t = (eng.stats["dispatches"] * DISPATCH_COST_S
                 + eng.stats["transfers"] * TRANSFER_COST_S)
    return {r.rid: r.out for r in done}, {
        "tokens": sum(len(out) for out in (r.out for r in done)),
        "wall_s": round(wall, 4),
        "engine_tokens_per_s": round(steps / max(modeled_t, 1e-12), 1),
        "dispatches_per_token": round(eng.stats["dispatches"] / steps, 4),
        "transfers_per_token": round(eng.stats["transfers"] / steps, 4),
    }


def _bitwise_rows() -> list[dict]:
    cfg = get_smoke_config("yi_6b")
    params = tf.init_params(jax.random.key(SEED), cfg)
    plan_cfg = cfg.replace(
        plan=NumericsPlan.uniform("interp-fused", cfg.n_layers))
    interp_cfg = cfg.replace(numerics="interp")
    got, plan_stats = _serve_once(plan_cfg, params)
    want, ref_stats = _serve_once(interp_cfg, params)
    assert got == want, ("uniform NumericsPlan drifted from the homogeneous "
                         "fused interp engine — plan plumbing is not pure")
    return [{
        "arch": "yi_6b", "engine": name, "tokens": st["tokens"],
        "engine_tokens_per_s": st["engine_tokens_per_s"],
        "dispatches_per_token": st["dispatches_per_token"],
        "bitwise_identical": True, "wall_s": st["wall_s"],
    } for name, st in (("uniform-plan", plan_stats),
                       ("homogeneous", ref_stats))]


def _auto_rows() -> list[dict]:
    rows = []
    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        params = tf.init_params(jax.random.key(SEED), cfg)
        rep = auto_plan(cfg, error_budget=BUDGET, verify=True, params=params)
        assert rep.measured_error is not None
        assert rep.measured_error <= BUDGET, \
            f"{arch}: measured error {rep.measured_error} > budget {BUDGET}"
        assert rep.modeled_tokens_per_s > rep.exact_tokens_per_s, \
            f"{arch}: assigned plan does not beat all-exact"
        _, serve_stats = _serve_once(cfg.replace(plan=rep.plan), params)
        interp_sites = sum(1 for _l, _s, a in rep.plan.assignments()
                           if a.interp)
        rows.append({
            "arch": arch, "budget": BUDGET,
            "predicted_error": round(rep.predicted_error, 6),
            "measured_error": round(rep.measured_error, 6),
            "modeled_tokens_per_s": round(rep.modeled_tokens_per_s, 1),
            "exact_tokens_per_s": round(rep.exact_tokens_per_s, 1),
            "speedup": round(rep.speedup, 4),
            "slots": ",".join(rep.plan.slot_keys()) or "-",
            "interp_sites": interp_sites,
            "flipped_to_exact": len(rep.flipped),
            **serve_stats,
        })
    return rows


def run():
    emit("plan_bitwise", _bitwise_rows())
    emit("plan_auto", _auto_rows(),
         cols=["arch", "budget", "predicted_error", "measured_error",
               "modeled_tokens_per_s", "exact_tokens_per_s", "speedup",
               "slots", "interp_sites", "flipped_to_exact", "tokens",
               "dispatches_per_token", "wall_s"])


if __name__ == "__main__":
    run()
