"""Sharded, AOT-warmed serving tier benchmark (DESIGN.md §17) -> BENCH_10.

Sweeps batch x bucket-table x mesh over two serving modes:

  offline   MLPerf-style max-throughput: submit the whole batch up front,
            measure tokens / wall-clock from first submit to last retire.
            The baseline is the PR-5 single-host fused engine (lazy jit):
            its measured window pays one admission compile per distinct
            prompt length, exactly what AOT warm-up moves to construction.
  online    latency-SLO: per-request TTFT (submit -> first emitted token)
            p50/p99 plus attainment against a fixed SLO. A warmed engine's
            TTFT carries zero compile (asserted: ``aot_misses == 0`` and
            steady-state ``aot_hits > 0``).

Every mesh row is decoded twice more under single-host engines — exact
numerics and a uniform interp-fused :class:`NumericsPlan` — and the token
streams are asserted **bitwise identical** to the sharded run before any
row is emitted (the GSPMD partitioning and the padded-bucket prefill must
not change a single token).

The sweep itself runs in a subprocess with
``--xla_force_host_platform_device_count=8`` (the tests' dry-run isolation
rule: the parent process keeps seeing one device); rows come back over
stdout and land in ``artifacts/bench/serve_sharded_{offline,online}.json``,
folded into ``BENCH_10.json`` by ``benchmarks.run``.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

from benchmarks.common import QUICK, emit

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
_MARK = "SERVE_SHARDED_ROWS:"

OFFLINE_COLS = ["mode", "mesh", "batch", "buckets", "tokens", "wall_s",
                "tok_s", "speedup_vs_lazy", "admit_dispatches",
                "packed_admits", "aot_hits", "aot_misses", "aot_reshards",
                "bitwise_exact", "bitwise_plan"]
ONLINE_COLS = ["mode", "mesh", "batch", "buckets", "ttft_p50_ms",
               "ttft_p99_ms", "slo_ms", "slo_attained", "tok_s",
               "aot_misses"]


def _worker() -> None:
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
    import time

    import jax
    import numpy as np

    from repro.configs.base import get_smoke_config
    from repro.launch.mesh import make_serve_mesh
    from repro.models import transformer as tf
    from repro.plan.schema import SlotSpec, plan_for
    from repro.serve import aot as aot_mod
    from repro.serve.engine import Request, ServeEngine

    assert len(jax.devices()) == 8, jax.devices()
    quick = os.environ.get("BENCH_QUICK", "0") == "1"

    cfg = get_smoke_config("yi_6b")
    cfg_plan = cfg.replace(plan=plan_for(cfg, backend="interp-fused",
                                         slot=SlotSpec(lookup_bits=6)))
    params = tf.init_params(jax.random.key(0), cfg)
    CACHE, MAX_NEW, SLOTS = 64, 8, 8
    BUCKETS = (8, 16, 32)
    batches = (4, 8) if quick else (4, 8, 16)
    meshes = ((1, 1), (2, 1)) if quick else ((1, 1), (2, 1), (2, 2), (4, 2))
    rng = np.random.default_rng(11)
    workloads = {b: [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
                     for n in rng.integers(3, 33, b)] for b in batches}

    def serve(engine, prompts, ttft=False):
        """Submit everything, run to drain; returns (tokens dict, wall
        seconds, per-request TTFT seconds)."""
        t0 = time.perf_counter()
        for i, p in enumerate(prompts):
            engine.submit(Request(i, p, max_new=MAX_NEW))
        first: dict[int, float] = {}
        while engine.step():
            if ttft:
                now = time.perf_counter()
                for r in list(engine.req) + list(engine.finished):
                    if r is not None and r.out and r.rid not in first:
                        first[r.rid] = now - t0
        engine._drain_pipeline()
        wall = time.perf_counter() - t0
        return ({r.rid: tuple(r.out) for r in engine.finished}, wall,
                [first[k] for k in sorted(first)] if ttft else [])

    # single-host references (exact + uniform plan), lazy PR-5 baseline
    refs, ref_plan, lazy_wall = {}, {}, {}
    for b in batches:
        eng = ServeEngine(cfg, params, slots=SLOTS, cache_len=CACHE)
        refs[b], lazy_wall[b], _ = serve(eng, workloads[b])
        engp = ServeEngine(cfg_plan, params, slots=SLOTS, cache_len=CACHE)
        ref_plan[b], _, _ = serve(engp, workloads[b])

    offline, online = [], []
    slo_s = 1.0  # generous CPU-host SLO; the point is the p99 column
    for data, tp in meshes:
        mesh = make_serve_mesh(data, tp)
        name = f"{data}x{tp}"
        for b in batches:
            kw = dict(slots=SLOTS, cache_len=CACHE, mesh=mesh,
                      aot_buckets=BUCKETS, max_pack=4)
            eng = ServeEngine(cfg, params, **kw)  # warm-up outside the clock
            got, wall, _ = serve(eng, workloads[b])
            assert got == refs[b], (
                f"sharded {name} batch {b}: exact tokens diverged")
            assert eng.stats["aot_misses"] == 0, eng.stats
            assert eng.stats["aot_hits"] > 0, eng.stats
            engp = ServeEngine(cfg_plan, params, **kw)
            gotp, _, _ = serve(engp, workloads[b])
            assert gotp == ref_plan[b], (
                f"sharded {name} batch {b}: uniform-plan tokens diverged")
            tokens = sum(len(v) for v in got.values())
            offline.append({
                "mode": "offline", "mesh": name, "batch": b,
                "buckets": ",".join(map(str, BUCKETS)), "tokens": tokens,
                "wall_s": wall, "tok_s": tokens / wall,
                "speedup_vs_lazy": lazy_wall[b] / wall,
                "admit_dispatches": eng.stats["admit_dispatches"],
                "packed_admits": eng.stats["packed_admits"],
                "aot_hits": eng.stats["aot_hits"],
                "aot_misses": eng.stats["aot_misses"],
                "aot_reshards": eng.stats["aot_reshards"],
                "bitwise_exact": True, "bitwise_plan": True,
            })
            eng2 = ServeEngine(cfg, params, **kw)
            got2, wall2, ttfts = serve(eng2, workloads[b], ttft=True)
            assert got2 == refs[b]
            tokens2 = sum(len(v) for v in got2.values())
            ts = np.asarray(sorted(ttfts))
            online.append({
                "mode": "online", "mesh": name, "batch": b,
                "buckets": ",".join(map(str, BUCKETS)),
                "ttft_p50_ms": float(np.percentile(ts, 50)) * 1e3,
                "ttft_p99_ms": float(np.percentile(ts, 99)) * 1e3,
                "slo_ms": slo_s * 1e3,
                "slo_attained": float((ts <= slo_s).mean()),
                "tok_s": tokens2 / wall2,
                "aot_misses": eng2.stats["aot_misses"],
            })
        aot_mod.clear_cache()  # next mesh pins different shardings

    print(_MARK + json.dumps({"offline": offline, "online": online}))


def run() -> None:
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.serve_sharded", "--worker"],
        capture_output=True, text=True, env=env, timeout=3000,
        cwd=str(pathlib.Path(__file__).resolve().parents[1]))
    if out.returncode != 0:
        raise RuntimeError(f"serve_sharded worker failed\nSTDOUT:\n"
                           f"{out.stdout}\nSTDERR:\n{out.stderr}")
    line = next(ln for ln in out.stdout.splitlines()
                if ln.startswith(_MARK))
    rows = json.loads(line[len(_MARK):])
    emit("serve_sharded_offline", rows["offline"], OFFLINE_COLS)
    emit("serve_sharded_online", rows["online"], ONLINE_COLS)


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker()
    else:
        run()
