"""Benchmark suite entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only a,b,c]

| module            | paper artifact                         |
|-------------------|----------------------------------------|
| table1            | Table I (proposed cols, runtime, LUB)  |
| table2            | Table II (LUT widths vs Remez)         |
| claim21           | SII-A Claim II.1 speedup + engines     |
| scaling           | SII-A O(R^-3) + exponential-in-bits    |
| batched_engine    | batched vs pooled generation, min-R    |
| fleet_compile     | fleet vs serial manifest compile/min-R |
| fig3_lub_sweep    | Figs 2-3 area-delay vs LUT height      |
| kernels_bench     | TPU adaptation: kernels + table accuracy |
| serve_path        | fused-library vs per-table decode numerics |
| decode_fused      | fused serve tick vs serial decode path |
| roofline_report   | SRoofline table from the dry-run sweep |
| segment_rom       | non-uniform (ROM v2) vs uniform layout |
| plan_serve        | per-layer NumericsPlan serving + auto-assigner |
| serve_sharded     | mesh-sharded + AOT-warmed serving tier |

After a run that produced them, the claim21 + batched_engine rows are
folded into ``artifacts/bench/BENCH_2.json``, the serve_path rows into
``BENCH_3.json``, the fleet_compile rows into ``BENCH_4.json``, and the
decode_fused rows into ``BENCH_5.json``, the segment_rom rows into
``BENCH_8.json``, the plan_serve rows into ``BENCH_9.json``, and the
serve_sharded rows into ``BENCH_10.json`` — the per-PR perf snapshots
tracked by the CI bench-smoke, segment-smoke, plan-smoke and shard-smoke
jobs. (``BENCH_6.json`` is written by the DSE study CLI,
``repro.launch.dse --emit-bench``, not by this runner.)

Snapshots go through ``repro.dse.record.update_snapshot``: every file is
schema-versioned and stamped with the seed, jax version and device
platform it was produced under, and a pre-existing unversioned snapshot
is backed up (``*.pre-schema.json``) instead of silently overwritten.
"""
from __future__ import annotations

import argparse
import os
import pathlib
import sys
import time

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "bench"

BENCH_SEED = 0  # every benchmark module keys its PRNGs off seed 0
QUICK_RUN = False  # set by main(); stamped into snapshot meta

# snapshot file -> {module -> tables folded into it}
_SNAPSHOTS = {
    "BENCH_2.json": {
        "claim21": ("claim21_search", "claim21_endtoend"),
        "batched_engine": ("batched_vs_pooled", "min_regions_search"),
    },
    "BENCH_3.json": {
        "serve_path": ("serve_path_decode", "serve_path_ensemble"),
    },
    "BENCH_4.json": {
        "fleet_compile": ("fleet_compile", "fleet_min_regions"),
    },
    "BENCH_5.json": {
        "decode_fused": ("decode_fused",),
    },
    "BENCH_7.json": {
        "chaos_serve": ("chaos_overhead", "chaos_faults", "chaos_recovery"),
    },
    "BENCH_8.json": {
        "segment_rom": ("segment_rom", "segment_serve"),
    },
    "BENCH_9.json": {
        "plan_serve": ("plan_bitwise", "plan_auto"),
    },
    "BENCH_10.json": {
        "serve_sharded": ("serve_sharded_offline", "serve_sharded_online"),
    },
}


def _emit_snapshots(ran: set) -> None:
    # refresh only the tables whose module ran THIS invocation (stale
    # per-table JSONs from an earlier run must not be stamped into the
    # snapshot), but keep the other modules' existing tables — a partial
    # --only run must not truncate the tracked snapshots
    from repro.dse.record import read_snapshot, update_snapshot

    for snap, sources in _SNAPSHOTS.items():
        snap_path = ART / snap
        fresh = {}
        for mod, tables in sources.items():
            if mod not in ran:
                continue
            for name in tables:
                path = ART / f"{name}.json"
                if path.exists():
                    # per-table files are themselves versioned envelopes
                    # (benchmarks.common.emit); legacy bare lists unwrap too
                    fresh[name] = read_snapshot(path).get(name)
        if fresh:
            update_snapshot(snap_path, fresh, seed=BENCH_SEED,
                            meta_extra={"quick": QUICK_RUN})
            print(f"\nwrote {snap_path} (refreshed {sorted(fresh)})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced precisions (CI-speed run)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    args = ap.parse_args()
    if args.quick:
        os.environ["BENCH_QUICK"] = "1"
    global QUICK_RUN
    QUICK_RUN = args.quick

    from benchmarks import (batched_engine, chaos_serve, claim21,
                            decode_fused, fig3_lub_sweep, fleet_compile,
                            kernels_bench, plan_serve, roofline_report,
                            scaling, segment_rom, serve_path, serve_sharded,
                            table1, table2)
    mods = {
        "table1": table1, "table2": table2, "claim21": claim21,
        "scaling": scaling, "batched_engine": batched_engine,
        "fleet_compile": fleet_compile,
        "fig3_lub_sweep": fig3_lub_sweep, "kernels_bench": kernels_bench,
        "serve_path": serve_path, "decode_fused": decode_fused,
        "chaos_serve": chaos_serve, "roofline_report": roofline_report,
        "segment_rom": segment_rom, "plan_serve": plan_serve,
        "serve_sharded": serve_sharded,
    }
    only = set(args.only.split(",")) if args.only else None
    if only and not only <= set(mods):
        sys.exit(f"unknown --only module(s): {sorted(only - set(mods))}")
    failures = []
    ran = set()
    for name, mod in mods.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        print(f"\n=== {name} ===", flush=True)
        try:
            mod.run()
            ran.add(name)
            print(f"--- {name}: {time.perf_counter()-t0:.1f}s", flush=True)
        except Exception as e:
            failures.append((name, repr(e)))
            print(f"--- {name} FAILED: {e!r}", flush=True)
    _emit_snapshots(ran)
    if failures:
        print(f"\n{len(failures)} benchmark(s) failed: {failures}")
        sys.exit(1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
