"""Benchmark suite entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

| module            | paper artifact                         |
|-------------------|----------------------------------------|
| table1            | Table I (proposed cols, runtime, LUB)  |
| table2            | Table II (LUT widths vs Remez)         |
| claim21           | SII-A Claim II.1 speedup               |
| scaling           | SII-A O(R^-3) + exponential-in-bits    |
| fig3_lub_sweep    | Figs 2-3 area-delay vs LUT height      |
| kernels_bench     | TPU adaptation: kernels + table accuracy |
| roofline_report   | SRoofline table from the dry-run sweep |
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced precisions (CI-speed run)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    if args.quick:
        os.environ["BENCH_QUICK"] = "1"

    from benchmarks import (claim21, fig3_lub_sweep, kernels_bench,
                            roofline_report, scaling, table1, table2)
    mods = {
        "table1": table1, "table2": table2, "claim21": claim21,
        "scaling": scaling, "fig3_lub_sweep": fig3_lub_sweep,
        "kernels_bench": kernels_bench, "roofline_report": roofline_report,
    }
    failures = []
    for name, mod in mods.items():
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        print(f"\n=== {name} ===", flush=True)
        try:
            mod.run()
            print(f"--- {name}: {time.perf_counter()-t0:.1f}s", flush=True)
        except Exception as e:
            failures.append((name, repr(e)))
            print(f"--- {name} FAILED: {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} benchmark(s) failed: {failures}")
        sys.exit(1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
