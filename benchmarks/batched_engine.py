"""Batched region engine vs the pooled per-region path (ISSUE 2 tentpole).

Two measurements, both on the reciprocal spec (the paper's headline design):

* ``batched_vs_pooled`` — the §II generation front half (envelopes + Eqn
  9-10 feasibility for every region) swept over the complete feasible range
  ``[min_R, in_bits]``, per engine, with speedup vs the pooled seed path.
* ``min_regions_search`` — the min-R query: the seed's linear scan from
  R=0 (which probes the most expensive heights first: a probe at R costs
  O(4^bits / 2^R)) vs the monotonicity-exploiting exponential-descent +
  binary search.

These rows feed artifacts/bench/BENCH_2.json (see benchmarks/run.py).
"""
from __future__ import annotations

import time

from benchmarks.common import QUICK, emit
from repro.api import ExploreConfig, Explorer
from repro.core.funcspec import get_spec


def _sweep_time(spec, engine: str, heights, repeat: int = 2) -> float:
    """Best-of-``repeat`` wall-clock (fresh session each run: every probe
    recomputes envelopes + feasibility, nothing is served from cache)."""
    best = float("inf")
    for _ in range(repeat):
        with Explorer(ExploreConfig(engine=engine)) as ex:
            t0 = time.perf_counter()
            for r in heights:
                ex.feasible(spec, r)
            best = min(best, time.perf_counter() - t0)
    return best


def run() -> list[dict]:
    bits = 12 if QUICK else 16
    spec = get_spec("recip", bits)
    with Explorer() as ex:
        min_r = ex.min_regions(spec)
    # the complete feasible range: every LUT height the design space exists at
    heights = list(range(min_r, spec.in_bits + 1))
    engines = ["pooled", "batched"]
    import jax

    if jax.default_backend() == "tpu":
        engines.append("pallas")  # interpret mode would swamp the timing
    rows = []
    base = None
    for engine in engines:
        dt = _sweep_time(spec, engine, heights)
        if engine == "pooled":
            base = dt
        rows.append({
            "engine": engine, "bits": bits,
            "R_sweep": f"{heights[0]}..{heights[-1]}",
            "regions_total": sum(1 << r for r in heights),
            "time_s": round(dt, 3),
            "speedup_vs_pooled": round(base / dt, 2) if base else 1.0,
        })
    emit("batched_vs_pooled", rows)

    # min-R search: seed linear scan vs exponential-descent + binary
    mr_bits = 10 if QUICK else 14
    mr_spec = get_spec("recip", mr_bits)
    with Explorer() as ex:
        t0 = time.perf_counter()
        linear = next((r for r in range(mr_spec.in_bits + 1)
                       if ex.feasible(mr_spec, r)), None)
        t_linear = time.perf_counter() - t0
    with Explorer() as ex:
        t0 = time.perf_counter()
        fast = ex.min_regions(mr_spec)
        t_fast = time.perf_counter() - t0
    assert fast == linear, (fast, linear)
    rows2 = [
        {"search": "linear-scan (seed)", "bits": mr_bits, "min_R": linear,
         "time_s": round(t_linear, 3), "speedup": 1.0},
        {"search": "exp-descent + binary", "bits": mr_bits, "min_R": fast,
         "time_s": round(t_fast, 3),
         "speedup": round(t_linear / t_fast, 2) if t_fast else float("inf")},
    ]
    emit("min_regions_search", rows2)
    return rows + rows2


if __name__ == "__main__":
    run()
