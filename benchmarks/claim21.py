"""Paper §II-A: Claim II.1 pruning speedup, plus the batched engine.

The paper reports the pruned scalar search makes 16-bit reciprocal design
space generation ~5x faster single-threaded. We time the four search
implementations on the exact searches the generator performs — the Eqn 7-8
a-interval divided-difference searches over every region's M/m envelopes —
and report the batched region engine (one array program over all regions)
alongside them, with a speedup-vs-seed column (seed = the paper's naive
scalar baseline). A second table times end-to-end generation per backend.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import QUICK, emit
from repro.api import ExploreConfig, Explorer
from repro.core import batched, searches
from repro.core.funcspec import get_spec

IMPLS = ["naive", "claim21", "vectorized", "hull"]


def run() -> list[dict]:
    bits = 12 if QUICK else 16
    r = 6 if QUICK else 8
    spec = get_spec("recip", bits)
    lo, hi = spec.region_bounds(r)
    # the generator's hot search: max/min divided differences over the M/m
    # envelopes of EVERY region (exactly what feasibility runs per R)
    big_m, small_m = batched.batched_envelopes(lo, hi)
    mt, st = big_m[:, 1:], small_m[:, 1:]
    n_regions, t_len = mt.shape
    rows = []
    base = None
    ref_vals = None
    for impl in IMPLS:
        t0 = time.perf_counter()
        v_lo = np.array([searches.max_dd(mt[i], st[i], impl)[0]
                         for i in range(n_regions)])
        v_hi = np.array([searches.min_dd(st[i], mt[i], impl)[0]
                         for i in range(n_regions)])
        dt = time.perf_counter() - t0
        if impl == "naive":
            base = dt
            ref_vals = (v_lo, v_hi)
        assert np.array_equal(v_lo, ref_vals[0]), impl
        assert np.array_equal(v_hi, ref_vals[1]), impl
        rows.append({
            "impl": impl, "regions": n_regions, "t_len": t_len,
            "time_ms": round(dt * 1e3, 2),
            "speedup_vs_seed": round(base / dt, 2) if base else 1.0,
        })
    t0 = time.perf_counter()
    b_lo = batched.batched_max_dd(mt, st)
    b_hi = batched.batched_min_dd(st, mt)
    dt = time.perf_counter() - t0
    assert np.array_equal(b_lo, ref_vals[0]) and np.array_equal(b_hi, ref_vals[1])
    rows.append({
        "impl": "batched-engine", "regions": n_regions, "t_len": t_len,
        "time_ms": round(dt * 1e3, 2),
        "speedup_vs_seed": round(base / dt, 2),
    })
    emit("claim21_search", rows)

    # end-to-end §II-A reproduction: full generation per backend. The scalar
    # impls run under the pooled engine (the batched engines bypass `impl`).
    e2e_bits, e2e_r = (10, 5) if QUICK else (14, 7)
    spec2 = get_spec("recip", e2e_bits)
    rows2 = []
    base = None
    widths = set()
    for impl in IMPLS:
        with Explorer(ExploreConfig(engine="pooled", impl=impl)) as ex:
            t0 = time.perf_counter()
            res = ex.explore_r(spec2, e2e_r)
            dt = time.perf_counter() - t0
        if impl == "naive":
            base = dt
        widths.add(str(res.design.lut_widths))
        rows2.append({
            "backend": f"pooled/{impl}", "bits": e2e_bits, "R": e2e_r,
            "gen_time_s": round(dt, 3),
            "speedup_vs_seed": round(base / dt, 2) if base else 1.0,
            "k": res.design.k, "widths": str(res.design.lut_widths),
        })
    with Explorer(ExploreConfig(engine="batched")) as ex:
        t0 = time.perf_counter()
        res = ex.explore_r(spec2, e2e_r)
        dt = time.perf_counter() - t0
    widths.add(str(res.design.lut_widths))
    rows2.append({
        "backend": "batched", "bits": e2e_bits, "R": e2e_r,
        "gen_time_s": round(dt, 3),
        "speedup_vs_seed": round(base / dt, 2),
        "k": res.design.k, "widths": str(res.design.lut_widths),
    })
    assert len(widths) == 1, f"backend changed the design: {widths}"
    emit("claim21_endtoend", rows2)
    return rows + rows2


if __name__ == "__main__":
    run()
