"""Paper §II-A: Claim II.1 pruning speedup.

The paper reports the pruned scalar search makes 16-bit reciprocal design
space generation ~5x faster single-threaded. We time the four search
implementations on the exact searches the generator performs (the M/m
envelope divided-difference sweeps of the largest region) and on the
end-to-end feasibility pass.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import QUICK, emit
from repro.core import searches
from repro.core.designspace import envelopes
from repro.core.funcspec import get_spec

IMPLS = ["naive", "claim21", "vectorized", "hull"]


def run() -> list[dict]:
    bits = 12 if QUICK else 16
    r = 6 if QUICK else 8
    spec = get_spec("recip", bits)
    lo, hi = spec.region_bounds(r)
    # the generator's hot search: max/min divided differences over M/m
    # envelopes of each region; region 0 has the steepest curvature
    m_env, m_env2 = envelopes(lo[0], hi[0])
    m_env, m_env2 = m_env[1:], m_env2[1:]  # drop the t=0 placeholder
    rows = []
    base = None
    for impl in IMPLS:
        t0 = time.perf_counter()
        v1 = searches.max_dd(m_env, m_env2, impl)
        v2 = searches.min_dd(m_env2, m_env, impl)
        dt = time.perf_counter() - t0
        if impl == "naive":
            base = dt
            ref = (v1[0], v2[0])
        rows.append({
            "impl": impl, "n": len(m_env),
            "time_ms": round(dt * 1e3, 2),
            "speedup_vs_naive": round(base / dt, 2) if base else 1.0,
            "max_dd": f"{v1[0]:.6g}", "min_dd": f"{v2[0]:.6g}",
        })
    # agreement check
    vals = {(r["max_dd"], r["min_dd"]) for r in rows}
    assert len(vals) == 1, f"impl disagreement: {vals}"
    emit("claim21_search", rows)

    # end-to-end §II-A reproduction: full generation under each search impl
    from repro.core.generate import generate_for_r
    e2e_bits, e2e_r = (10, 5) if QUICK else (14, 7)
    spec2 = get_spec("recip", e2e_bits)
    rows2 = []
    base = None
    for impl in IMPLS:
        t0 = time.perf_counter()
        res = generate_for_r(spec2, e2e_r, impl=impl)
        dt = time.perf_counter() - t0
        if impl == "naive":
            base = dt
        rows2.append({
            "impl": impl, "bits": e2e_bits, "R": e2e_r,
            "gen_time_s": round(dt, 3),
            "speedup_vs_naive": round(base / dt, 2) if base else 1.0,
            "k": res.design.k, "widths": str(res.design.lut_widths),
        })
    assert len({r["widths"] for r in rows2}) == 1, "impl changed the design"
    emit("claim21_endtoend", rows2)
    return rows + rows2


if __name__ == "__main__":
    run()
